"""LM-scale Co-Boosting (core.distributed) + runtime step tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, TrainConfig
from repro.core.distributed import (
    client_lm_logits,
    coboost_distill_loss,
    dhs_embeds,
    ee_update_lm,
    ensemble_lm_logits,
)
from repro.models import init_lm, lm_forward
from repro.runtime import make_distill_step_lm, make_train_step
from repro.utils import tree_stack

CFG = ModelConfig(
    name="tiny", family="dense", num_layers=2, d_model=32, num_heads=4,
    num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=96, scan_layers=True,
    remat=False, dtype="float32", param_dtype="float32",
)


def _clients(k=3):
    return tree_stack([init_lm(CFG, jax.random.key(i)) for i in range(k)])


def test_ensemble_lm_logits_matches_manual():
    stacked = _clients(3)
    batch = {"tokens": jax.random.randint(jax.random.key(9), (2, 8), 0, CFG.vocab_size)}
    w = jnp.asarray([0.5, 0.25, 0.25])
    got = ensemble_lm_logits(stacked, CFG, batch, w)
    manual = 0.0
    for i, wi in enumerate([0.5, 0.25, 0.25]):
        p_i = jax.tree_util.tree_map(lambda x: x[i], stacked)
        manual = manual + wi * lm_forward(p_i, CFG, batch)[0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(manual), rtol=1e-5, atol=1e-5)


def test_client_lm_logits_shape():
    stacked = _clients(2)
    batch = {"tokens": jnp.zeros((3, 6), jnp.int32)}
    out = client_lm_logits(stacked, CFG, batch)
    assert out.shape == (2, 3, CFG.vocab_size)


def test_dhs_embeds_eps_norm():
    stacked = _clients(2)
    embeds = jax.random.normal(jax.random.key(0), (2, 6, CFG.d_model)) * 0.02
    batch = {"embeds": embeds}
    out = dhs_embeds(stacked, CFG, batch, jnp.asarray([0.5, 0.5]), jax.random.key(1), 0.1)
    delta = np.asarray(out["embeds"] - embeds).reshape(2, -1)
    np.testing.assert_allclose(np.linalg.norm(delta, axis=1), 0.1, rtol=1e-3)


def test_ee_update_lm_simplex():
    stacked = _clients(3)
    w = jnp.full((3,), 1 / 3)
    moved = False
    # when every per-client gradient shares a sign, the sign step renormalizes
    # back to uniform — a valid fixed point that depends on the PRNG draw, so
    # probe a few batches and require at least one to move the weights
    for seed in range(5):
        batch = {"embeds": jax.random.normal(jax.random.key(2 * seed), (4, 6, CFG.d_model)) * 0.02}
        labels = jax.random.randint(jax.random.key(2 * seed + 1), (4,), 0, CFG.vocab_size)
        w2 = np.asarray(ee_update_lm(w, stacked, CFG, batch, labels, mu=0.05))
        assert np.all(w2 >= 0) and abs(w2.sum() - 1) < 1e-5
        moved = moved or not np.allclose(w2, 1 / 3)
    assert moved


def test_distill_step_reduces_kd_loss():
    stacked = _clients(2)
    server = init_lm(CFG, jax.random.key(42))
    tc = TrainConfig(optimizer="sgdm", learning_rate=0.2)
    step = make_distill_step_lm(CFG, tc)
    opt_state = step.optimizer.init(server)
    w = jnp.asarray([0.5, 0.5])
    batch = {"embeds": jax.random.normal(jax.random.key(3), (2, 8, CFG.d_model)) * 0.02}
    jit_step = jax.jit(step)
    losses = []
    for i in range(5):
        server, opt_state, m = jit_step(server, opt_state, stacked, w, batch, jnp.asarray(i))
        losses.append(float(m["kd"]))
    assert losses[-1] < losses[0], losses


def test_train_step_microbatch_equivalence():
    """microbatches=2 gradient accumulation must match the single-batch
    step (same SGD update up to float tolerance)."""
    tc1 = TrainConfig(optimizer="sgd", learning_rate=0.1, microbatches=1)
    tc2 = TrainConfig(optimizer="sgd", learning_rate=0.1, microbatches=2)
    params = init_lm(CFG, jax.random.key(0))
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (4, 8), 0, CFG.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (4, 8), 0, CFG.vocab_size),
    }
    outs = []
    for tc in (tc1, tc2):
        step = make_train_step(CFG, tc)
        st = step.optimizer.init(params)
        p2, _, m = jax.jit(step)(params, st, batch, jnp.asarray(0))
        outs.append(p2)
    flat1 = jax.tree_util.tree_leaves(outs[0])
    flat2 = jax.tree_util.tree_leaves(outs[1])
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)

"""Shared grad-parity harness for the differentiable kernel path.

One fixture layer, three jobs (importable from any test module — pytest
collects nothing from here):

  * randomized *loss-op cases* (:func:`loss_case`) covering the geometry the
    kernels must survive — non-tile-aligned batch/vocab tails, bf16 inputs
    promoted at the call boundary, extreme logits, degenerate ensembling
    weights — plus :func:`assert_loss_grad_parity`, which differentiates the
    op under ``backend="ref"`` (plain autodiff of the jnp oracle) and
    ``backend="pallas-interpret"`` (the fused Pallas VJP, bit-for-bit the
    TPU kernel's math) and asserts every cotangent set agrees to
    :data:`TOL`;
  * ``check_grads``-grade numerical validation of the kernel VJPs against
    finite differences (:func:`check_kernel_grads`);
  * per-method *end-to-end one-step runners* (:func:`run_method`) for all
    five methods (coboosting, DENSE, F-DAFL, F-ADI, FedDF) on the grouped
    client bank, so tests can assert that a full fused-epoch optimizer
    step — generator phase, EE, distillation, every ``jax.grad`` inside —
    lands on the same server params under ``ref`` and ``pallas-interpret``.

This harness IS the parity contract that retired ``driver="legacy"``: the
oracle is the ref backend of the fused driver, not a second python loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.test_util import check_grads

from repro.kernels import ensemble_kl, ghm_ce
from repro.kernels.dispatch import BackendPolicy

TOL = 1e-4
INTERP = "pallas-interpret"


# ---------------------------------------------------------------------------
# tree assertions


def tree_max_diff(a, b) -> float:
    return max(
        float(jnp.max(jnp.abs(u.astype(jnp.float32) - v.astype(jnp.float32))))
        for u, v in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def assert_tree_close(got, want, tol: float = TOL) -> None:
    """Leaf-wise allclose with ``tol`` as both rtol and atol (the rtol term
    keeps extreme-logit cases meaningful: tolerance scales with |want|)."""
    for u, v in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# randomized loss-op cases


def loss_case(
    seed: int,
    k: int,
    b: int,
    v: int,
    *,
    dtype=jnp.float32,
    logit_scale: float = 2.0,
    w_mode: str = "softmax",
) -> Dict[str, Any]:
    """One randomized (K, B, V) ensemble-loss case.

    ``dtype`` below f32 is generated in that dtype and PROMOTED to f32 at
    the boundary — the kernels' contract is f32 compute, so parity at
    :data:`TOL` is asserted on what the op actually receives, not on bf16
    rounding. ``logit_scale`` stretches the logits (±1e4 exercises the
    online-softmax residuals at the edge of f32). ``w_mode``: "softmax"
    (generic simplex point), "onehot" (a single surviving client) or "zero"
    (degenerate all-zero weights — lse falls back to log V)."""
    ks = jax.random.split(jax.random.key(seed), 5)
    cl = (jax.random.normal(ks[0], (k, b, v)) * logit_scale).astype(dtype)
    st = (jax.random.normal(ks[1], (b, v)) * logit_scale).astype(dtype)
    if w_mode == "softmax":
        w = jax.nn.softmax(jax.random.normal(ks[2], (k,)))
    elif w_mode == "onehot":
        w = jax.nn.one_hot(int(jax.random.randint(ks[2], (), 0, k)), k)
    elif w_mode == "zero":
        w = jnp.zeros((k,))
    else:
        raise ValueError(f"unknown w_mode {w_mode!r}")
    return {
        "cl": cl.astype(jnp.float32),
        "st": st.astype(jnp.float32),
        "w": w,
        "labels": jax.random.randint(ks[3], (b,), 0, v),
        "ct": jax.random.normal(ks[4], (b,)),
    }


EPS32 = 1.2e-7  # f32 machine epsilon, rounded up


def _cond_atols(case: Dict[str, Any], tol: float) -> Tuple[float, float]:
    """Conditioning floor of the parity comparison, per cotangent set.

    At extreme logit scales S the per-sample factor (log p − log q − KL)
    cancels ~S-sized terms, so BOTH arms carry ~ε·S absolute rounding in the
    logits cotangents — and the w cotangent contracts that against the
    ~S-sized client logits, squaring the scale. Below those floors ref and
    kernel legitimately disagree (the ref differs from itself by as much
    under reassociation); at ordinary scales both floors sit far under
    ``tol`` and the strict 1e-4 contract is what's asserted. Returns
    ``(atol_logits, atol_w)``."""
    s = max(float(jnp.max(jnp.abs(case["cl"]))), float(jnp.max(jnp.abs(case["st"]))), 1.0)
    ct = max(float(jnp.max(jnp.abs(case["ct"]))), 1.0)
    return max(tol, 4 * EPS32 * s * ct), max(tol, 4 * EPS32 * s * s * ct)


def assert_loss_grad_parity(
    op: str,
    case: Dict[str, Any],
    tol: float = TOL,
    **op_kwargs,
) -> None:
    """ref-vs-interpret gradients for every cotangent set of one loss op.

    ``op`` is "ensemble_kl" (grads for client_logits, student_logits, w) or
    "ghm_ce" (grads for client_logits, w; labels are integer). Both arms go
    through the public dispatched op so the ref arm exercises the real
    "ref bypasses the custom_vjp" route. Tolerances: rtol ``tol``
    throughout; atol ``tol`` lifted to the f32 conditioning floor of the
    case (see :func:`_cond_atols`) so extreme-logit sweeps assert the
    tightest bound f32 admits."""
    cl, st, w, labels, ct = (case[x] for x in ("cl", "st", "w", "labels", "ct"))
    atol_logits, atol_w = _cond_atols(case, tol)
    if op == "ensemble_kl":

        def f(backend, cl, st, w):
            return jnp.vdot(ensemble_kl(cl, st, w, backend=backend, **op_kwargs), ct)

        got = jax.grad(partial(f, INTERP), argnums=(0, 1, 2))(cl, st, w)
        want = jax.grad(partial(f, "ref"), argnums=(0, 1, 2))(cl, st, w)
        atols = (atol_logits, atol_logits, atol_w)
    elif op == "ghm_ce":

        def f(backend, cl, w):
            return jnp.vdot(ghm_ce(cl, labels, w, backend=backend, **op_kwargs), ct)

        got = jax.grad(partial(f, INTERP), argnums=(0, 1))(cl, w)
        want = jax.grad(partial(f, "ref"), argnums=(0, 1))(cl, w)
        atols = (atol_logits, atol_w)
    else:
        raise ValueError(f"unknown loss op {op!r}")
    for u, v, atol in zip(got, want, atols):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), rtol=tol, atol=atol)


def check_kernel_grads(f, args, atol: float = 1e-2, rtol: float = 1e-2) -> None:
    """Finite-difference validation of a kernel-backed scalar loss (rev
    mode, order 1) — the ``check_grads``-grade part of the contract."""
    check_grads(f, args, order=1, modes=("rev",), atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# end-to-end method runners (fused driver, grouped client bank)


METHODS = ("coboosting", "dense", "f_dafl", "f_adi", "feddf")


def build_tiny_market(
    seed: int = 0,
    classes: int = 4,
    shape: Tuple[int, int, int] = (8, 8, 3),
    epochs: int = 2,
    archs: Tuple[str, ...] = ("mlp", "mlp"),
) -> Dict[str, Any]:
    """A tiny heterogeneous-market setup shared by the per-method parity
    tests: grouped client bank (cfg.ensemble_impl default), two clients,
    synthetic images, plus the FedDF validation split."""
    from repro.config.train import OFLConfig
    from repro.data import make_synth_images
    from repro.fed import build_market

    cfg = OFLConfig(
        num_clients=len(archs), local_epochs=1, local_batch_size=16,
        epochs=epochs, gen_iters=2, batch_size=8, latent_dim=8, buffer_batches=2,
    )
    x, y = make_synth_images(seed, classes, 24, shape)
    applies, params, _, _ = build_market(seed, x, y, cfg, classes, archs=list(archs))
    val_x, _ = make_synth_images(seed + 1, classes, 2 * cfg.batch_size, shape)
    return {
        "cfg": cfg, "applies": applies, "params": params,
        "classes": classes, "shape": shape, "val_x": jnp.asarray(val_x),
    }


def run_method(method: str, backend: str, setup: Dict[str, Any], epochs: Optional[int] = None):
    """Run one method end-to-end under the fused driver with every
    dispatched op pinned to ``backend``; returns the final OFLState. The
    run includes at least one full optimizer step per phase (generator,
    EE where applicable, distillation), so its server params witness every
    backward the backend routes."""
    from repro.core import (
        default_image_setup,
        run_adi_baseline,
        run_coboosting,
        run_feddf,
        run_generator_baseline,
    )
    from repro.models.cnn import cnn_apply, init_cnn

    cfg, applies, params = setup["cfg"], setup["applies"], setup["params"]
    classes, shape = setup["classes"], setup["shape"]
    if epochs is not None:
        cfg = dataclasses.replace(cfg, epochs=epochs)
    cfg = dataclasses.replace(cfg, backend=BackendPolicy(default=backend))
    server_apply = partial(cnn_apply, "mlp")
    sp = init_cnn(jax.random.key(99), "mlp", classes, shape)
    key = jax.random.key(0)
    if method == "feddf":
        return run_feddf(applies, params, server_apply, sp, setup["val_x"], cfg, key)
    if method == "f_adi":
        return run_adi_baseline(applies, params, server_apply, sp, shape, cfg, classes, key)
    gen_apply, gp = default_image_setup(jax.random.key(5), cfg, classes, shape)
    if method == "coboosting":
        return run_coboosting(
            applies, params, server_apply, sp, gen_apply, gp, cfg, classes, key
        )
    return run_generator_baseline(
        method, applies, params, server_apply, sp, gen_apply, gp, cfg, classes, key
    )


def assert_method_backend_parity(
    method: str, setup: Dict[str, Any], epochs: Optional[int] = None, tol: float = TOL
) -> None:
    """The end-to-end contract: ``ref`` and ``pallas-interpret`` runs of one
    method land on the same server params (and ensembling weights)."""
    ref = run_method(method, "ref", setup, epochs=epochs)
    ker = run_method(method, INTERP, setup, epochs=epochs)
    assert tree_max_diff(ref.server_params, ker.server_params) < tol, method
    np.testing.assert_allclose(
        np.asarray(ref.weights), np.asarray(ker.weights), atol=tol
    )

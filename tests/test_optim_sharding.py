"""Optimizers, schedules, sharding rule resolution, checkpointing."""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import list_checkpoints, load_checkpoint, save_checkpoint
from repro.config import TrainConfig
from repro.optim import (
    adam,
    adamw,
    clip_by_global_norm,
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
    make_optimizer,
    sgd,
    sgdm,
)
from repro.optim.optimizers import apply_updates
from repro.sharding import resolve_rule
from repro.sharding.partition import infer_param_specs


@pytest.mark.parametrize("opt_name", ["sgd", "sgdm", "adam", "adamw"])
def test_optimizers_descend_quadratic(opt_name):
    tc = TrainConfig(optimizer=opt_name, learning_rate=0.1)
    opt = make_optimizer(tc)
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray([1.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.sum(jnp.square(p["b"]))

    l0 = float(loss(params))
    for i in range(50):
        g = jax.grad(loss)(params)
        u, state = opt.update(g, state, params, jnp.asarray(i, jnp.int32))
        params = apply_updates(params, u)
    assert float(loss(params)) < 0.05 * l0, opt_name


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(clipped["a"])), 1.0, rtol=1e-5)
    same = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(same["a"], g["a"], rtol=1e-5)


def test_schedules():
    c = constant_schedule(0.1)
    assert float(c(jnp.asarray(0))) == pytest.approx(0.1)
    cos = cosine_schedule(1.0, 100)
    assert float(cos(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    wc = linear_warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.asarray(0))) < float(wc(jnp.asarray(9)))


# ---------------------------------------------------------------------------
# sharding


def test_resolve_rule_divisibility_fallback():
    axes = {"data": 16, "model": 16}
    # 9 heads don't divide 16 -> replicated; 64 do -> model
    assert resolve_rule(("fsdp", "heads", None), (576, 9, 64), axes)[1] is None
    assert resolve_rule(("fsdp", "heads", None), (4096, 64, 128), axes)[1] == "model"
    # experts 8 < 16 -> fall to None
    assert resolve_rule(("experts", "fsdp", None), (8, 4096, 14336), axes)[0] is None
    assert resolve_rule(("experts", "fsdp", None), (128, 4096, 1536), axes)[0] == "model"
    # batch folds pod+data when both divide
    axes3 = {"pod": 2, "data": 16, "model": 16}
    spec = resolve_rule(("batch", None), (256, 128), axes3)
    assert spec[0] == ("pod", "data")


def test_resolve_rule_never_reuses_axis():
    axes = {"data": 4, "model": 4}
    spec = resolve_rule(("tp", "tp"), (8, 8), axes)
    used = [s for s in spec if s is not None]
    assert len(used) <= 1  # second dim cannot reuse "model"


def test_infer_param_specs_no_mesh_is_replicated():
    params = {"block": {"attn": {"wq": jnp.zeros((8, 4, 2))}}}
    specs = infer_param_specs(params)
    assert specs["block"]["attn"]["wq"] == P()


# ---------------------------------------------------------------------------
# checkpoint


def test_checkpoint_roundtrip():
    tree = {
        "layer": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": jnp.ones((3,))},
        "step": jnp.asarray(7),
    }
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 10, tree, {"note": "test"})
        save_checkpoint(d, 20, tree)
        assert list_checkpoints(d) == [10, 20]
        loaded = load_checkpoint(d)  # latest
        np.testing.assert_array_equal(loaded["layer"]["w"], np.asarray(tree["layer"]["w"]))
        loaded10 = load_checkpoint(d, 10)
        np.testing.assert_array_equal(loaded10["step"], 7)

"""Unit tests for the trip-count-aware HLO cost walker (the §Roofline
measurement instrument — these encode the caveats it exists to fix)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_cost import HloCost, parse_computations

HLO_WHILE = """
HloModule t
%wrapped_compare_computation (a: s32[], b: s32[]) -> pred[] {
  %a = s32[] parameter(0)
  %b = s32[] parameter(1)
  ROOT %c = pred[] compare(%a, %b), direction=LT
}
%body.1 (arg: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %arg = (s32[], f32[64,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%arg), index=1
  %d = f32[64,64]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[64,64]{1,0} all-reduce(%d), to_apply=%wrapped_compare_computation
  ROOT %t = (s32[], f32[64,64]{1,0}) tuple(%i, %ar)
}
%cond.2 (arg: (s32[], f32[64,64])) -> pred[] {
  %arg = (s32[], f32[64,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
ENTRY %main.3 (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[64,64]{1,0}) tuple(%z, %p)
  %w = (s32[], f32[64,64]{1,0}) while(%tup), condition=%cond.2, body=%body.1
  ROOT %r = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_scaling_flops_and_collectives():
    t = HloCost(HLO_WHILE).totals()
    assert t["flops"] == pytest.approx(5 * 2 * 64**3)
    assert t["all-reduce"] == 5 * 64 * 64 * 4
    assert t["coll_total"] == t["all-reduce"]


def test_tuple_types_with_index_comments_parse():
    """/*index=N*/ comments inside tuple types contain '=' and broke the
    first parser (every while was silently skipped)."""
    hlo = """
ENTRY %main.1 (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %w = (s32[], bf16[2,3]{1,0}, /*index=2*/f32[4]{0}) while(%p), condition=%c, body=%b
  ROOT %r = f32[4]{0} get-tuple-element(%w), index=2
}
"""
    comps = parse_computations(hlo)
    ops = [i.op for i in comps["main.1"].instrs]
    assert "while" in ops


def test_matches_compiled_scan_exactly():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    t = HloCost(compiled.as_text()).totals()
    assert t["flops"] == pytest.approx(7 * 2 * 64**3, rel=0.01)
    # raw cost_analysis counts ONE iteration — the caveat this walker fixes
    raw = compiled.cost_analysis()
    if isinstance(raw, list):  # older jax returns [dict]
        raw = raw[0]
    raw = raw["flops"]
    assert raw == pytest.approx(2 * 64**3, rel=0.01)


def test_dynamic_slice_counts_slice_not_operand():
    hlo = """
ENTRY %main.1 (p: f32[100,64], i: s32[]) -> f32[1,64] {
  %p = f32[100,64]{1,0} parameter(0)
  %i = s32[] parameter(1)
  ROOT %ds = f32[1,64]{0,1} dynamic-slice(%p, %i), dynamic_slice_sizes={1,64}
}
"""
    t = HloCost(hlo).totals()
    assert t["bytes"] == 2 * 1 * 64 * 4  # 2×slice, not the 100×64 operand

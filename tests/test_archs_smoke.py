"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture family (≤2 layers / one interleave group, d_model≤128,
≤4 experts) runs one forward + one train step on CPU; output shapes and
finiteness are asserted. Decode-capable archs also run prefill + one decode
step."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (
    INPUT_SHAPES,
    TrainConfig,
    arch_supports_shape,
    get_arch,
    list_archs,
    reduced_variant,
)
from repro.models import init_lm, init_lm_state, lm_decode, lm_forward, lm_loss, lm_prefill
from repro.runtime import make_train_step

ARCHS = list_archs()
B, S = 2, 32


def _smoke_cfg(name):
    cfg = reduced_variant(get_arch(name))
    return cfg.replace(dtype="float32", param_dtype="float32")


def _batch(cfg, key, batch=B, seq=S):
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(key, (batch, seq, cfg.frontend_dim)),
            "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        p = cfg.num_prefix_tokens
        return {
            "tokens": jax.random.randint(key, (batch, seq - p), 0, cfg.vocab_size),
            "prefix": jax.random.normal(key, (batch, p, cfg.frontend_dim)),
            "labels": jax.random.randint(key, (batch, seq - p), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _smoke_cfg(arch)
    params = init_lm(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits, aux = jax.jit(lambda p, b: lm_forward(p, cfg, b))(params, batch)
    expect_s = S if cfg.family != "vlm" else S  # prefix + text = S for vlm
    assert logits.shape == (B, expect_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_or_finite(arch):
    cfg = _smoke_cfg(arch)
    params = init_lm(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    tc = TrainConfig(optimizer="sgdm", learning_rate=0.05, total_steps=10)
    step_fn = make_train_step(cfg, tc)
    opt_state = step_fn.optimizer.init(params)
    jit_step = jax.jit(step_fn)
    l0 = None
    for i in range(3):
        params, opt_state, metrics = jit_step(params, opt_state, batch, jnp.asarray(i))
        assert bool(jnp.isfinite(metrics["loss"])), arch
        if l0 is None:
            l0 = float(metrics["loss"])
    assert float(metrics["loss"]) < l0 + 1e-3, f"{arch}: loss did not move down"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill_shapes(arch):
    full = get_arch(arch)
    cfg = _smoke_cfg(arch)
    if full.is_encoder_only:
        pytest.skip("encoder-only: no decode step (DESIGN.md skip)")
    params = init_lm(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    state = init_lm_state(cfg, B, S + 4)
    logits, state = jax.jit(lambda p, b, s: lm_prefill(p, cfg, b, s))(params, batch, state)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    logits2, state = jax.jit(lambda p, t, s, pos: lm_decode(p, cfg, t, s, pos))(
        params, tok, state, jnp.asarray(S, jnp.int32)
    )
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The registered full config carries the exact assigned hyperparams."""
    cfg = get_arch(arch)
    sheet = {
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936, 128, 8),
        "mixtral-8x7b": (32, 4096, 32, 8, 32000, 8, 2),
        "xlstm-125m": (12, 768, 4, 4, 50304, 0, 0),
        "hubert-xlarge": (48, 1280, 16, 16, 504, 0, 0),
        "smollm-135m": (30, 576, 9, 3, 49152, 0, 0),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 32064, 0, 0),
        "qwen3-32b": (64, 5120, 64, 8, 151936, 0, 0),
        "granite-3-2b": (40, 2048, 32, 8, 49155, 0, 0),
        "internlm2-20b": (48, 6144, 48, 8, 92544, 0, 0),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 65536, 16, 2),
    }[arch]
    assert (
        cfg.num_layers,
        cfg.d_model,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.vocab_size,
        cfg.num_experts,
        cfg.experts_per_token,
    ) == sheet


def test_skip_matrix():
    """Exactly the documented skips: encoder-only decode + full-attention
    long_500k."""
    skips = []
    for a in ARCHS:
        cfg = get_arch(a)
        for s, sh in INPUT_SHAPES.items():
            if arch_supports_shape(cfg, sh):
                skips.append((a, s))
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    assert ("mixtral-8x7b", "long_500k") not in skips  # SWA ring cache
    assert ("jamba-v0.1-52b", "long_500k") not in skips
    assert ("xlstm-125m", "long_500k") not in skips
    assert len(skips) == 8

"""Per-kernel allclose sweeps (shapes × dtypes) against the pure-jnp
oracles, run in Pallas interpret mode on CPU (requested explicitly —
``backend="auto"`` resolves to the jnp ref off-TPU, see
repro.kernels.dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    ensemble_kl,
    ensemble_kl_ref,
    flash_attention,
    flash_attention_ref,
    ghm_ce,
    ghm_ce_ref,
    kernel_arm,
)


INTERP = "pallas-interpret"


@pytest.mark.parametrize("k,b,v", [(1, 4, 64), (3, 13, 700), (8, 32, 2048), (5, 8, 511)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("temp", [1.0, 4.0])
def test_ensemble_kl_matches_ref(k, b, v, dtype, temp):
    cl = (jax.random.normal(jax.random.key(0), (k, b, v)) * 3).astype(dtype)
    st = (jax.random.normal(jax.random.key(1), (b, v)) * 3).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (k,)))
    got = ensemble_kl(cl, st, w, temperature=temp, backend=INTERP)
    want = ensemble_kl_ref(cl, st, w, temp)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_ensemble_kl_zero_for_identical():
    cl = jnp.stack([jax.random.normal(jax.random.key(0), (6, 100))] * 3)
    st = cl[0]
    w = jnp.full((3,), 1 / 3)
    got = ensemble_kl(cl, st, w, temperature=2.0, backend=INTERP)
    np.testing.assert_allclose(got, np.zeros(6), atol=1e-5)


@pytest.mark.parametrize("b", [1, 3, 5])
def test_ensemble_kl_small_batch_pads_to_tile(b):
    """B < 8 must pad the batch up to the (8, 128) tile, not shrink the
    tile below VPU alignment (the old ``min(block_b, b)`` bug)."""
    cl = jax.random.normal(jax.random.key(0), (3, b, 200)) * 2
    st = jax.random.normal(jax.random.key(1), (b, 200)) * 2
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (3,)))
    got = ensemble_kl(cl, st, w, temperature=4.0, backend=INTERP)
    assert got.shape == (b,)
    np.testing.assert_allclose(got, ensemble_kl_ref(cl, st, w, 4.0), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k,b,v", [(2, 5, 33), (4, 11, 531), (10, 16, 1024)])
@pytest.mark.parametrize("weighted", [True, False])
def test_ghm_ce_matches_ref(k, b, v, weighted):
    cl = jax.random.normal(jax.random.key(0), (k, b, v)) * 2
    lbl = jax.random.randint(jax.random.key(1), (b,), 0, v)
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (k,)))
    got = ghm_ce(cl, lbl, w, weighted=weighted, backend=INTERP)
    want = ghm_ce_ref(cl, lbl, w, weighted)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_ghm_ce_small_batch_pads_to_tile():
    """The B=5 pin for the pad-to-tile fix (labels pad along with the batch)."""
    cl = jax.random.normal(jax.random.key(0), (4, 5, 96)) * 2
    lbl = jax.random.randint(jax.random.key(1), (5,), 0, 96)
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (4,)))
    got = ghm_ce(cl, lbl, w, backend=INTERP)
    assert got.shape == (5,)
    np.testing.assert_allclose(got, ghm_ce_ref(cl, lbl, w), rtol=1e-5, atol=1e-5)


def test_ghm_ce_difficulty_weighting_downweights_easy():
    """An easy sample (huge label logit) must contribute ~0 weighted CE."""
    v = 64
    cl = jnp.zeros((1, 2, v))
    cl = cl.at[0, 0, 3].set(30.0)  # sample 0: trivially classified as 3
    lbl = jnp.asarray([3, 5])
    w = jnp.ones((1,))
    out = np.asarray(ghm_ce(cl, lbl, w, backend=INTERP))
    assert out[0] < 1e-6  # d≈0 ⇒ weighted CE ≈ 0
    assert out[1] > 1.0  # hard sample keeps its CE


@pytest.mark.parametrize(
    "b,sq,h,kh,hd,causal,window,cap",
    [
        (2, 64, 4, 2, 32, True, 0, 0.0),
        (1, 40, 4, 4, 16, True, 0, 0.0),
        (2, 33, 2, 1, 32, False, 0, 0.0),
        (1, 96, 4, 2, 32, True, 24, 0.0),
        (1, 48, 2, 2, 64, True, 0, 20.0),
        (3, 128, 8, 4, 64, True, 0, 0.0),
    ],
)
def test_flash_attention_matches_ref(b, sq, h, kh, hd, causal, window, cap):
    q = jax.random.normal(jax.random.key(0), (b, sq, h, hd))
    k = jax.random.normal(jax.random.key(1), (b, sq, kh, hd))
    v = jax.random.normal(jax.random.key(2), (b, sq, kh, hd))
    got = flash_attention(q, k, v, causal=causal, window=window, softcap=cap, backend=kernel_arm(), block_q=16, block_kv=32)
    want = flash_attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    b, s, h, kh, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(jax.random.key(0), (b, s, h, hd)).astype(dtype)
    k = jax.random.normal(jax.random.key(1), (b, s, kh, hd)).astype(dtype)
    v = jax.random.normal(jax.random.key(2), (b, s, kh, hd)).astype(dtype)
    got = flash_attention(q, k, v, causal=True, backend=kernel_arm(), block_q=16, block_kv=16)
    want = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )

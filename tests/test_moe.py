"""MoE dispatch-strategy equivalence: the GShard einsum path, the
scatter/gather path, and the dense oracle must agree when capacity is ample
(no drops), across shapes and expert counts."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.models.moe import init_moe, moe_apply_einsum, moe_apply_scatter, moe_ref


def _cfg(e, k, d=32, f=48, cap=8.0, group=0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=d, num_heads=4, num_kv_heads=4,
        d_ff=f, moe_d_ff=f, vocab_size=64, num_experts=e, experts_per_token=k,
        moe_capacity_factor=cap, moe_group_size=group, dtype="float32",
        param_dtype="float32",
    )


@pytest.mark.parametrize("e,k", [(4, 1), (4, 2), (8, 2), (16, 4)])
@pytest.mark.parametrize("group", [0, 8])
def test_dispatch_strategies_agree_with_oracle(e, k, group):
    cfg = _cfg(e, k, group=group)
    params = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y_ref, aux_ref = moe_ref(params, x, cfg)
    y_ein, aux_ein = moe_apply_einsum(params, x, cfg)
    y_sca, aux_sca = moe_apply_scatter(params, x, cfg)
    np.testing.assert_allclose(y_ein, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(y_sca, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_ein), float(aux_ref), rtol=1e-5)
    np.testing.assert_allclose(float(aux_sca), float(aux_ref), rtol=1e-5)


def test_einsum_and_scatter_drop_identically():
    """With a tight capacity both paths drop the SAME token-slots."""
    cfg = _cfg(4, 2, cap=0.5)
    params = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 32, cfg.d_model))
    y_ein, _ = moe_apply_einsum(params, x, cfg)
    y_sca, _ = moe_apply_scatter(params, x, cfg)
    np.testing.assert_allclose(y_ein, y_sca, rtol=2e-4, atol=2e-4)


def test_aux_loss_penalizes_imbalance():
    """A router forced onto one expert must yield a larger aux loss than a
    balanced router (Switch LB loss lower bound is 1 at balance)."""
    cfg = _cfg(4, 1)
    params = init_moe(jax.random.key(0), cfg, jnp.float32)
    # all-positive inputs + an all-ones column-0 router ⇒ every token's
    # expert-0 logit is large positive ⇒ total collapse onto expert 0
    x = jnp.abs(jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))) + 0.5
    _, aux_bal = moe_ref(params, x, cfg)
    collapse_router = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
    biased = dict(params, router=collapse_router)
    _, aux_bias = moe_ref(biased, x, cfg)
    assert float(aux_bias) > float(aux_bal)
    assert float(aux_bias) > 3.5  # ≈ E for total collapse


def test_grads_flow_through_both_paths():
    cfg = _cfg(4, 2)
    params = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))

    for impl in (moe_apply_einsum, moe_apply_scatter):
        def loss(p):
            y, aux = impl(p, x, cfg)
            return jnp.sum(jnp.square(y)) + aux

        g = jax.grad(loss)(params)
        for path in ("wi", "wg", "wo", "router"):
            assert float(jnp.max(jnp.abs(g[path]))) > 0, (impl.__name__, path)

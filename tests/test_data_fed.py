"""Data pipeline + federated partition tests."""
from __future__ import annotations

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.config.train import OFLConfig
from repro.data import (
    c_cls_partition,
    dirichlet_partition,
    iid_partition,
    lognormal_resize,
    make_synth_images,
    make_token_stream,
    partition_dataset,
)

SETTINGS = dict(max_examples=15, deadline=None)


def test_synth_images_shapes_and_range():
    x, y = make_synth_images(0, 6, 20, (16, 16, 3))
    assert x.shape == (120, 16, 16, 3) and y.shape == (120,)
    assert x.min() >= -1.0 and x.max() <= 1.0
    assert sorted(np.unique(y)) == list(range(6))


def test_synth_images_class_separability():
    """Nearest-class-mean classification must beat chance by a wide margin —
    otherwise the OFL benchmarks would be vacuous."""
    x, y = make_synth_images(0, 6, 60, (16, 16, 3))
    xt, yt = make_synth_images(1, 6, 30, (16, 16, 3))
    means = np.stack([x[y == c].reshape(-1, 16 * 16 * 3).mean(0) for c in range(6)])
    d = ((xt.reshape(-1, 16 * 16 * 3)[:, None] - means[None]) ** 2).sum(-1)
    acc = (d.argmin(1) == yt).mean()
    # ≥3× chance for a linear-in-pixels classifier (CNN clients reach ~1.0;
    # see the market logs in tests/test_ofl_integration.py)
    assert acc > 0.5, acc


def test_synth_images_deterministic():
    a = make_synth_images(3, 4, 10, (8, 8, 3))
    b = make_synth_images(3, 4, 10, (8, 8, 3))
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_token_stream_learnable_structure():
    d = make_token_stream(0, 128, 4, 64)
    assert d["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(d["tokens"][:, 1:], d["labels"][:, :-1])
    assert d["tokens"].max() < 128 and d["tokens"].min() >= 0


@given(st.integers(2, 12), st.sampled_from([0.05, 0.1, 0.5, 10.0]))
@settings(**SETTINGS)
def test_dirichlet_partition_is_a_partition(n_clients, alpha):
    rng = np.random.RandomState(0)
    labels = rng.randint(0, 6, size=600)
    parts = dirichlet_partition(0, labels, n_clients, alpha)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == 600
    assert len(np.unique(all_idx)) == 600  # disjoint cover
    assert all(len(p) >= 2 for p in parts)


def test_dirichlet_alpha_controls_skew():
    rng = np.random.RandomState(0)
    labels = rng.randint(0, 10, size=5000)

    def skew(alpha):
        parts = dirichlet_partition(0, labels, 10, alpha)
        # mean per-client entropy of the label histogram (lower = more skew)
        ents = []
        for p in parts:
            h = np.bincount(labels[p], minlength=10).astype(float)
            h /= h.sum()
            ents.append(-(h[h > 0] * np.log(h[h > 0])).sum())
        return np.mean(ents)

    assert skew(0.05) < skew(10.0)


@given(st.integers(2, 8), st.integers(1, 5))
@settings(**SETTINGS)
def test_c_cls_partition_class_limit(n_clients, c):
    rng = np.random.RandomState(1)
    labels = rng.randint(0, 6, size=800)
    parts = c_cls_partition(0, labels, n_clients, c)
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert len(np.unique(all_idx)) == len(all_idx)
    for p in parts:
        if len(p):
            assert len(np.unique(labels[p])) <= c


def test_lognormal_resize_skews_sizes():
    labels = np.random.RandomState(0).randint(0, 6, size=1200)
    parts = iid_partition(0, labels, 8)
    sized = lognormal_resize(0, parts, sigma=1.2)
    sizes = np.array([len(p) for p in sized])
    assert sizes.max() > 2 * sizes.min()
    even = lognormal_resize(0, parts, sigma=0.0)
    assert [len(p) for p in even] == [len(p) for p in parts]


def test_partition_dispatch():
    labels = np.random.RandomState(0).randint(0, 6, size=600)
    for part, kw in (("dirichlet", {}), ("c_cls", {}), ("iid", {})):
        cfg = OFLConfig(num_clients=4, partition=part)
        parts = partition_dataset(0, labels, cfg)
        assert len(parts) == 4
    with pytest.raises(ValueError):
        partition_dataset(0, labels, OFLConfig(partition="nope"))

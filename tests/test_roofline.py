"""Roofline analysis unit tests: HLO collective parser + term math."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import INPUT_SHAPES, get_arch
from repro.roofline import V5E, collective_bytes, model_flops, roofline_report

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,4096]{1,0} all-gather(%p0), dimensions={1}
  %ar.1 = bf16[64,64]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[8,256]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = f32[16,16]{1,0} all-to-all(%z), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-gather-start(%v), dimensions={0}
  %agd = f32[2,2]{1,0} all-gather-done(%ags)
  %dot = f32[128,256]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}
}
"""


def test_collective_parser_kinds_and_bytes():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 128 * 4096 * 4 + 2 * (2 * 2 * 4)  # ag + ag-start tuple
    assert out["all-reduce"] == 64 * 64 * 2
    assert out["reduce-scatter"] == 8 * 256 * 4
    assert out["all-to-all"] == 16 * 16 * 4
    assert out["collective-permute"] == 4 * 4 * 2
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
    )


def test_collective_parser_ignores_compute_ops():
    out = collective_bytes("%d = f32[128,128]{1,0} dot(%a, %b)\n%c = f32[4]{0} add(%x, %y)")
    assert out["total"] == 0


def test_model_flops_train_vs_decode():
    cfg = get_arch("smollm-135m")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    n = cfg.param_count(active_only=True)
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert de == pytest.approx(2 * n * 128)


def test_moe_uses_active_params():
    cfg = get_arch("qwen3-moe-235b-a22b")
    assert cfg.param_count(active_only=True) < 0.15 * cfg.param_count()


def test_roofline_report_on_tiny_compiled():
    """End-to-end on a real compiled program (1 device)."""

    def f(a, b):
        return jnp.tanh(a @ b)

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(f).lower(a, a).compile()
    rep = roofline_report(compiled, num_chips=1)
    assert rep["hlo_flops_per_device"] >= 2 * 256**3
    assert rep["dominant"] in ("compute", "memory", "collective")
    assert rep["fits_hbm"]
    assert rep["compute_s"] > 0 and rep["memory_s"] > 0

"""Paged KV-cache pool tests.

 * fail-fast EngineConfig/pool validation (pre-device-allocation, PR 3
   arg-audit style);
 * randomized admit/append/evict property test on the allocator: free-list
   conservation, no page leaks, no double-allocation, exhaustion raises;
 * the acceptance shape/size pin: the paged state's HBM footprint is
   ``pool_pages × page_size``-shaped — NOT ``slots × max_len``-shaped — and
   shrinks when the pool does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.models import init_lm, init_lm_state
from repro.serve import EngineConfig, KVPool, ServeEngine


def _mk(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, scan_layers=False,
        remat=False, dtype="float32", param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# fail-fast validation


def test_engine_config_rejects_bad_paged_knobs():
    """Every inconsistent knob combination dies at CONSTRUCTION with a clear
    message — before any device allocation."""
    with pytest.raises(ValueError, match="power of two"):
        EngineConfig(page_size=12)
    with pytest.raises(ValueError, match="power of two"):
        EngineConfig(page_size=0)
    with pytest.raises(ValueError, match="multiple of"):
        EngineConfig(max_seq=40, page_size=16)
    with pytest.raises(ValueError, match="at least one page"):
        EngineConfig(pool_pages=1, max_slots=4)
    with pytest.raises(ValueError, match="kv_layout"):
        EngineConfig(kv_layout="paged2")
    with pytest.raises(ValueError, match=">= 1"):
        EngineConfig(max_slots=0)
    # dense layout does not care about page knobs
    EngineConfig(kv_layout="dense", page_size=12, max_seq=40)
    # and a consistent paged config passes
    EngineConfig(pool_pages=8, max_slots=4, prefill_bucket=32, page_size=16, max_seq=64)


def test_pool_floor_bills_pages_against_model_cache_len():
    """The pool-vs-burst floor lives in KVPool (it needs the model): it
    bills whole PAGES per minimal admission, but never more than the slot's
    ring — so tight SWA pools that a token-level or window-blind bound would
    spuriously reject are accepted."""
    with pytest.raises(ValueError, match="exhaust the pool"):
        # 4 pages < 4 slots × 2 pages per bucket_min(32) admission
        KVPool(_mk(), EngineConfig(pool_pages=4, max_slots=4, prefill_bucket=32,
                                   page_size=16, max_seq=64))
    with pytest.raises(ValueError, match="exhaust the pool"):
        # bills PAGES, not tokens: 6×16=96 tokens >= 4×24 tokens, but a
        # 24-token bucket occupies ceil(24/16)=2 whole pages → 8 > 6
        KVPool(_mk(), EngineConfig(pool_pages=6, max_slots=4, prefill_bucket=24,
                                   page_size=16, max_seq=48))
    # an 8-token SWA ring is ONE page per slot no matter the bucket — the
    # same 4-page pool that fails above backs all 4 slots here
    pool = KVPool(_mk(sliding_window=8),
                  EngineConfig(pool_pages=4, max_slots=4, prefill_bucket=32,
                               page_size=16, max_seq=64))
    assert pool.pages_per_slot == 1 and pool.n_pages == 4


def test_pool_rejects_starved_capacity():
    cfg = _mk()
    # bypass EngineConfig's own pool_pages >= max_slots guard, so the pool's
    # page-billed floor (pages_min >= 1 per slot) is what trips
    ecfg = EngineConfig(max_slots=4, max_seq=32, prefill_bucket=1, page_size=16, pool_pages=0)
    object.__setattr__(ecfg, "pool_pages", 2)  # frozen: simulate a raw config
    with pytest.raises(ValueError, match="exhaust the pool"):
        KVPool(cfg, ecfg)


# ---------------------------------------------------------------------------
# allocator property test


def test_pool_randomized_invariants():
    """Random admit/append/evict sequences: pages partition exactly into
    free + owned, no page is ever owned twice, eviction conserves, and
    over-allocation raises instead of double-booking."""
    cfg = _mk()
    ecfg = EngineConfig(max_slots=6, max_seq=64, prefill_bucket=16, page_size=16)
    pool = KVPool(cfg, ecfg)
    rng = np.random.RandomState(3)
    live = set()

    def check():
        owned_all = [p for s in live for p in pool.owned(s)]
        assert len(owned_all) == len(set(owned_all)), "page double-booked"
        assert pool.free_pages + len(owned_all) == pool.n_pages, "free-list leak"
        assert pool.pages_in_use == len(owned_all)

    for step in range(300):
        op = rng.randint(3)
        if op == 0 and len(live) < ecfg.max_slots:  # admit
            slot = next(s for s in range(ecfg.max_slots) if s not in live)
            want = int(rng.randint(1, pool.pages_per_slot + 1))
            if want <= pool.free_pages:
                pages = pool.alloc(slot, want)
                assert len(pages) == want and len(set(pages)) == want
                live.add(slot)
            else:
                with pytest.raises(RuntimeError, match="exhausted"):
                    pool.alloc(slot, want)
                pool.free_slot(slot)  # alloc failed: slot owns nothing
        elif op == 1 and live:  # append (idempotent growth)
            slot = rng.choice(sorted(live))
            before = pool.owned(slot)
            want = int(rng.randint(1, pool.pages_per_slot + 1))
            if max(0, want - len(before)) <= pool.free_pages:
                pages = pool.alloc(slot, want)
                assert pages[: len(before)] == before, "growth reordered pages"
                assert len(pages) == max(want, len(before))
        elif op == 2 and live:  # evict
            slot = rng.choice(sorted(live))
            owned = set(pool.owned(slot))
            freed = set(pool.free_slot(slot))
            assert freed == owned
            live.discard(slot)
        check()

    for slot in sorted(live):
        pool.free_slot(slot)
    assert pool.free_pages == pool.n_pages and pool.pages_in_use == 0


def test_pool_randomized_refcount_invariants():
    """Extends the allocator property test to SHARED pages: random
    cold-admit / splice-attach / pin / unpin / CoW / evict sequences,
    checking after every step that free + allocated partitions
    ``range(n_pages)``, every page's refcount equals its slot-table
    memberships plus its prefix-cache pins, no page returns to the free
    list while its refcount is positive, and a copy-on-write page never
    aliases a page another table or pin still holds."""
    cfg = _mk()
    ecfg = EngineConfig(max_slots=6, max_seq=64, prefill_bucket=16, page_size=16)
    pool = KVPool(cfg, ecfg)
    rng = np.random.RandomState(7)
    tables = {}  # slot -> [pages]: shadow of the pool's ownership
    pins = {}  # page -> pin count: shadow of the prefix-cache pins

    def refs():
        r = dict(pins)
        for pages in tables.values():
            for p in pages:
                r[p] = r.get(p, 0) + 1
        return {p: c for p, c in r.items() if c > 0}

    def check():
        model = refs()
        assert pool.pages_in_use == len(model), "allocated-set drift"
        assert pool.free_pages == pool.n_pages - len(model), "partition broken"
        for p in range(pool.n_pages):
            # a page with live references must never be free (refcount 0)
            assert pool.refcount(p) == model.get(p, 0)
        for slot, pages in tables.items():
            assert pool.owned(slot) == pages

    for _ in range(400):
        op = rng.randint(6)
        clean = [s for s in range(ecfg.max_slots) if s not in tables]
        if op == 0 and clean:  # cold admit: fresh pages at refcount 1
            want = int(rng.randint(1, pool.pages_per_slot + 1))
            if want <= pool.free_pages:
                tables[clean[0]] = list(pool.alloc(clean[0], want))
        elif op == 1 and clean and tables:  # splice: shared head + fresh tail
            donor = rng.choice(sorted(tables))
            slot, k = clean[0], int(rng.randint(1, len(tables[donor]) + 1))
            shared = tables[donor][:k]
            pool.attach(slot, shared)
            tables[slot] = list(shared)
            grow = int(rng.randint(0, pool.pages_per_slot - k + 1))
            if 0 < grow <= pool.free_pages:
                tables[slot] = list(pool.alloc(slot, k + grow))
        elif op == 2 and pool.pages_in_use:  # prefix-cache pin
            page = int(rng.choice(sorted(refs())))
            pool.incref(page)
            pins[page] = pins.get(page, 0) + 1
        elif op == 3 and pins:  # drop a pin
            page = int(rng.choice(sorted(pins)))
            went_free = pool.decref(page)
            pins[page] -= 1
            if pins[page] == 0:
                del pins[page]
            assert went_free == (refs().get(page, 0) == 0)
        elif op == 4 and tables:  # copy-on-write a table entry
            slot = rng.choice(sorted(tables))
            idx = int(rng.randint(len(tables[slot])))
            old = tables[slot][idx]
            was_shared = pool.refcount(old) > 1
            if was_shared and pool.free_pages == 0:
                with pytest.raises(RuntimeError, match="exhausted"):
                    pool.cow(slot, idx)
            else:
                o, n = pool.cow(slot, idx)
                assert o == old
                if was_shared:
                    # the private copy aliases NOTHING still referenced
                    assert n != old and refs().get(n, 0) == 0
                    assert pool.refcount(n) == 1
                    tables[slot][idx] = n
                else:
                    assert n == old  # exclusively owned: no copy needed
        elif op == 5 and tables:  # evict: only orphans reach the free list
            slot = rng.choice(sorted(tables))
            pages = tables.pop(slot)
            freed = set(pool.free_slot(slot))
            model = refs()
            assert freed == {p for p in pages if model.get(p, 0) == 0}
        check()

    for slot in sorted(tables):
        pool.free_slot(slot)
    tables.clear()
    for page in sorted(pins):
        for _ in range(pins[page]):
            pool.decref(page)
    assert pool.free_pages == pool.n_pages and pool.pages_in_use == 0
    with pytest.raises(RuntimeError, match="not allocated"):
        pool.attach(0, [0])  # a stale (freed) id must never splice


def test_pool_donate_then_reset_no_leak():
    """``reset()`` clears the donate/adopt staging bookkeeping AND the
    per-page refcounts: a handoff staged (or even donated) before reset must
    not leak a reservation or a stale refcount onto a reissued page id —
    every page is reissuable exactly once afterwards. The staging-id counter
    is the one thing that survives: handoffs sealed before reset must never
    collide with reservations staged after it."""
    cfg = _mk()
    pool = KVPool(cfg, EngineConfig(max_slots=2, max_seq=64, page_size=16))
    sid, staged = pool.stage(2)  # an in-flight handoff reservation
    pages = pool.alloc(5, 2)  # a live slot (id clear of the sid namespace)
    pool.incref(pages[0])  # and a prefix-cache pin on one of its pages
    donated = pool.donate(sid)
    assert set(donated) == set(staged) and pool.staged_ids == []
    sid2, _ = pool.stage(1)  # a second handoff left IN FLIGHT across reset
    assert sid2 > sid  # sids never recycle
    pool.reset()
    assert pool.staged_ids == [] and pool.pages_in_use == 0
    assert pool.free_pages == pool.n_pages
    assert pool.refcount(pages[0]) == 0 and pool.refcount(staged[0]) == 0
    got = pool.alloc(5, pool.n_pages)  # every id hands out exactly once
    assert sorted(got) == list(range(pool.n_pages))
    pool.reset()
    sid3, _ = pool.stage(1)  # monotonic across resets too
    assert sid3 > sid2


def test_pool_handoff_donate_adopt():
    """The handoff protocol: ``donate`` releases a staging reservation back
    to the free list; ``adopt`` hands fresh ids to a CLEAN slot (adopting
    on top of live pages would orphan them — it must raise) and conserves
    the free+owned partition like any alloc."""
    cfg = _mk()
    pool = KVPool(cfg, EngineConfig(max_slots=2, max_seq=64, page_size=16))
    staged = pool.alloc(0, 2)  # the sending side's in-flight reservation
    got = pool.adopt(1, 2)  # the receiving side: fresh ids, not the staged ones
    assert len(got) == 2 and not set(got) & set(staged)
    with pytest.raises(RuntimeError, match="clean slot"):
        pool.adopt(1, 1)  # slot 1 is live — adopting again would orphan pages
    freed = pool.donate(0)
    assert set(freed) == set(staged)
    assert pool.free_pages + pool.pages_in_use == pool.n_pages
    assert pool.pages_in_use == 2  # only the adopted pages remain owned
    # donated ids are reissuable to the next staged prefill
    again = pool.alloc(0, 2)
    assert set(again) <= set(freed) | set(range(pool.n_pages))


def test_pool_table_row_padding():
    """Padding entries point at the scratch page — never at page 0, which is
    allocatable (an idle slot's ride-along write through a 0 padding entry
    would clobber page 0's owner)."""
    cfg = _mk()
    pool = KVPool(cfg, EngineConfig(max_slots=2, max_seq=64, page_size=16))
    pages = pool.alloc(1, 2)
    row = pool.table_row(1)
    assert row.shape == (pool.pages_per_slot,)
    assert list(row[:2]) == pages
    assert (row[2:] == pool.scratch_page).all()
    assert pool.scratch_page == pool.n_pages  # one past the pool: unallocatable


# ---------------------------------------------------------------------------
# HBM footprint scaling (acceptance criterion)


def _attn_cache_bytes(state):
    return sum(
        leaf.nbytes
        for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]
        for key in [jax.tree_util.keystr(path)]
        if "k_pages" in key or "v_pages" in key or key.endswith("['k']") or key.endswith("['v']")
    )


def test_paged_footprint_scales_with_pool_not_slots():
    """The pool buffer is (pool_pages, page_size, ...)-shaped: shrinking
    pool_pages shrinks HBM; the dense rectangle is pinned to slots × max_len
    no matter how little of it is live."""
    cfg = _mk()
    slots, max_seq, ps = 8, 256, 16
    kh, hd, groups = cfg.num_kv_heads, cfg.head_dim_, cfg.num_layers
    itemsize = 4  # float32

    dense = init_lm_state(cfg, slots, max_seq)
    assert _attn_cache_bytes(dense) == 2 * groups * slots * max_seq * kh * hd * itemsize

    for pool_pages in (32, 64):
        paged = init_lm_state(cfg, slots, max_seq, kv_pages=pool_pages, kv_page_size=ps)
        got = _attn_cache_bytes(paged)
        assert got == 2 * groups * pool_pages * ps * kh * hd * itemsize
        assert got < _attn_cache_bytes(dense)
    # halving the pool halves the footprint — pages, not slots, set the bill
    small = _attn_cache_bytes(init_lm_state(cfg, slots, max_seq, kv_pages=32, kv_page_size=ps))
    big = _attn_cache_bytes(init_lm_state(cfg, slots, max_seq, kv_pages=64, kv_page_size=ps))
    assert big == 2 * small


def test_admit_burst_exceeding_pool_is_atomic():
    """A burst whose bucketed prefills outbill the free pages is rejected
    BEFORE any slot pop / page alloc / dispatch — the engine stays clean and
    a smaller burst still admits."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    # 8 pages of 8 tokens: satisfies the construction floor (4 slots × 16
    # bucket_min), but LONGER prompts bill 4 pages each — the case only the
    # admission-time check can catch
    eng = ServeEngine(
        cfg, params,
        EngineConfig(
            max_slots=4, max_seq=32, max_new=4, prefill_bucket=16,
            page_size=8, pool_pages=8,
        ),
    )
    prompts = [np.arange(20, dtype=np.int32) % cfg.vocab_size] * 4  # 16 pages billed
    with pytest.raises(RuntimeError, match="cannot admit this burst"):
        eng.admit_many([(p, 2) for p in prompts])
    assert sorted(eng.free_slots) == [0, 1, 2, 3]  # no slot leaked
    assert eng.pool.free_pages == 8 and eng.pool.pages_in_use == 0  # no page leaked
    assert eng.stats["admitted"] == 0 and eng.stats["prefill_dispatches"] == 0
    slots = eng.admit_many([(prompts[0], 2), (prompts[1], 2)])  # retry smaller: fine
    assert len(slots) == 2 and eng.pool.pages_in_use == 8


def test_chunk_page_exhaustion_leaves_engine_unchanged():
    """Decode-time growth past the pool raises BEFORE any mutation: the
    stale set, the pool free list, and the page table survive intact —
    partial commitment would either re-open the stale-row clobber or leave
    a slot owning pages its device table never maps."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    eng = ServeEngine(
        cfg, params,
        EngineConfig(
            max_slots=2, max_seq=64, max_new=32, decode_chunk=16,
            prefill_bucket=8, page_size=8, pool_pages=4,
        ),
    )
    # two 8-token prompts (1 page each) whose budgets need 3 pages each —
    # the chunk-time bill (4 new pages) exceeds the 2 remaining
    s0, s1 = eng.admit_many([(np.arange(8, dtype=np.int32), 16)] * 2)
    eng._stale_slots.add("sentinel")  # must survive the failed ensure
    free_before = eng.pool.free_pages
    owned_before = {s: eng.pool.owned(s) for s in (s0, s1)}
    table_before = np.asarray(eng._state.page_table).copy()
    with pytest.raises(RuntimeError, match="engine state is unchanged"):
        eng.decode_chunk()
    assert "sentinel" in eng._stale_slots
    assert eng.pool.free_pages == free_before
    assert {s: eng.pool.owned(s) for s in (s0, s1)} == owned_before
    np.testing.assert_array_equal(np.asarray(eng._state.page_table), table_before)
    assert eng.stats["decode_chunks"] == 0  # nothing dispatched


def test_engine_paged_state_uses_pool_shapes():
    """End-to-end: a ServeEngine built with a small explicit pool carries the
    pool-shaped cache in its device state (and still serves correctly —
    parity is pinned in test_serve)."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    ecfg = EngineConfig(
        max_slots=2, max_seq=64, max_new=8, prefill_bucket=16, page_size=16, pool_pages=6,
    )
    eng = ServeEngine(cfg, params, ecfg)
    leaves = {
        jax.tree_util.keystr(p): l
        for p, l in jax.tree_util.tree_flatten_with_path(eng._state.kv)[0]
    }
    pages = [l for k, l in leaves.items() if "k_pages" in k or "v_pages" in k]
    # (G, P+1, ps, KH, hd): pool pages plus the one scratch page idle slots
    # write through — a constant, not a per-slot cost
    assert pages and all(l.shape[1:3] == (7, 16) for l in pages)
    assert eng._state.page_table.shape == (2, 64 // 16)
    assert eng.pool.n_pages == 6 and eng.pool.scratch_page == 6

"""Unified BackendPolicy dispatch: resolve() rules, alias precedence, and the
deprecated CLI knobs (--kernel-backend / --attn-backend / --decode-backend)
still steering their ops through the policy."""
from __future__ import annotations

import jax
import pytest

from repro.config.model import ModelConfig
from repro.config.train import OFLConfig
from repro.kernels.dispatch import (
    BACKEND_OPS,
    BackendPolicy,
    KERNEL_BACKENDS,
    policy_from_flags,
    resolve,
    resolve_backend,
)

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# resolve()


@pytest.mark.parametrize("op", BACKEND_OPS)
def test_resolve_auto_by_platform(op):
    assert resolve(op, "auto", platform="tpu") == "pallas"
    assert resolve(op, "auto", platform="cpu") == "ref"
    assert resolve(op, "auto", platform="gpu") == "ref"
    assert resolve(op, None, platform="cpu") == "ref"


@pytest.mark.parametrize("op", BACKEND_OPS)
def test_resolve_explicit_values(op):
    assert resolve(op, "ref", platform="cpu") == "ref"
    assert resolve(op, "pallas-interpret", platform="cpu") == "pallas-interpret"
    assert resolve(op, "pallas", platform="tpu") == "pallas"
    with pytest.raises(ValueError, match="requires a TPU"):
        resolve(op, "pallas", platform="cpu")


def test_resolve_validates_op_and_backend():
    with pytest.raises(ValueError, match="unknown backend op"):
        resolve("matmul", "auto")
    with pytest.raises(ValueError, match="unknown loss backend"):
        resolve("loss", "cuda")


def test_resolve_backend_shim_unchanged():
    """The original single-knob entry keeps its exact semantics."""
    assert resolve_backend("ref") == "ref"
    expected = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert resolve_backend("auto") == expected


# ---------------------------------------------------------------------------
# BackendPolicy


def test_policy_per_op_fallback():
    pol = BackendPolicy(default="ref", attn="pallas-interpret")
    assert pol.for_op("attn") == "pallas-interpret"
    assert pol.for_op("loss") == "ref"
    assert pol.for_op("decode") == "ref"
    assert pol.resolve("loss", platform="cpu") == "ref"
    assert pol.replace(decode="ref").for_op("decode") == "ref"


def test_policy_validates_on_construction():
    with pytest.raises(ValueError):
        BackendPolicy(default="cuda")
    with pytest.raises(ValueError):
        BackendPolicy(loss="jnp")
    with pytest.raises(ValueError, match="unknown backend op"):
        BackendPolicy().for_op("matmul")


# ---------------------------------------------------------------------------
# deprecated flag routing


def test_policy_from_flags_unified():
    pol = policy_from_flags(backend="ref")
    assert all(pol.for_op(op) == "ref" for op in BACKEND_OPS)
    # nothing given: all-auto
    assert policy_from_flags() == BackendPolicy()


@pytest.mark.parametrize(
    "kwargs, op",
    [
        ({"kernel_backend": "ref"}, "loss"),
        ({"attn_backend": "ref"}, "attn"),
        ({"decode_backend": "ref"}, "decode"),
    ],
)
def test_deprecated_flags_forward_and_warn(kwargs, op):
    with pytest.deprecated_call():
        pol = policy_from_flags(**kwargs)
    assert pol.for_op(op) == "ref"
    # the other ops keep the auto default
    for other in BACKEND_OPS:
        if other != op:
            assert pol.for_op(other) == "auto"


def test_deprecated_flags_can_be_silenced():
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pol = policy_from_flags(kernel_backend="ref", warn=False)
    assert pol.for_op("loss") == "ref"


# ---------------------------------------------------------------------------
# config alias precedence


def test_ofl_config_alias_precedence():
    # alias only: steers the loss op, other ops stay auto
    cfg = OFLConfig(kernel_backend="ref")
    assert cfg.backend_for("loss") == "ref"
    assert cfg.backend_for("attn") == "auto"
    # explicit policy wins over the alias
    cfg = OFLConfig(kernel_backend="ref", backend=BackendPolicy(loss="pallas-interpret"))
    assert cfg.backend_for("loss") == "pallas-interpret"
    # default-of-defaults
    assert OFLConfig().backend_for("loss") == "auto"


def test_model_config_alias_precedence():
    cfg = ModelConfig(name="t", family="dense", attn_backend="ref", decode_backend="pallas-interpret")
    assert cfg.backend_for("attn") == "ref"
    assert cfg.backend_for("decode") == "pallas-interpret"
    pol = BackendPolicy(default="ref")
    cfg = cfg.replace(backend=pol)
    assert cfg.backend_for("attn") == "ref"
    assert cfg.backend_for("decode") == "ref"
    cfg.validate()  # aliases still pass validation alongside a policy


def test_cli_parsers_accept_old_and_new_flags():
    """The launch entry points still accept every pre-policy invocation and
    route it through policy_from_flags."""
    from repro.launch.ofl import main as _  # noqa: F401 (import builds parser deps)
    import repro.launch.serve as serve

    p = serve.build_parser()
    args = p.parse_args(["--attn-backend", "ref", "--decode-backend", "ref"])
    with pytest.deprecated_call():
        pol = policy_from_flags(
            backend=args.backend,
            attn_backend=args.attn_backend,
            decode_backend=args.decode_backend,
        )
    assert pol.for_op("attn") == "ref" and pol.for_op("decode") == "ref"
    args = p.parse_args(["--backend", "ref"])
    assert args.attn_backend is None and args.decode_backend is None
    assert policy_from_flags(backend=args.backend) == BackendPolicy(default="ref")

"""Property sweep: loss-kernel VJP parity on randomized/adversarial shapes.

The slow lane of the grad contract (tests/grad_harness.py): ``ensemble_kl``
and ``ghm_ce`` gradients must match the jnp oracle to ≤1e-4 over randomized
(K, B, V) geometry INCLUDING the cases the tile machinery papers over —
non-tile-aligned tails (B=5, V off the 128 lane), bf16 inputs promoted at
the call boundary, extreme ±1e4 logits at the edge of f32 softmax, and
degenerate ensembling weights (all-zero, one-hot).

Runs under Hypothesis when it is installed; the container image may not
ship it (no new installs allowed), so the same generator is also driven by
a seeded explicit sweep — the property and its edge cases are asserted
either way, Hypothesis just adds shrinking + more draws.
"""
from __future__ import annotations

import jax.numpy as jnp
import pytest

from grad_harness import assert_loss_grad_parity, loss_case

pytestmark = [pytest.mark.slow]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# the named adversarial corners, always swept explicitly
EDGE_CASES = [
    # (seed, k, b, v, dtype, logit_scale, w_mode)
    (0, 3, 5, 130, jnp.float32, 2.0, "softmax"),  # B=5, V off the 128 lane
    (1, 2, 5, 96, jnp.float32, 2.0, "softmax"),  # sub-lane vocab tail
    (2, 4, 8, 257, jnp.bfloat16, 2.0, "softmax"),  # bf16 promoted at boundary
    (3, 3, 13, 700, jnp.bfloat16, 2.0, "onehot"),
    (4, 2, 8, 128, jnp.float32, 1e4, "softmax"),  # extreme ±1e4 logits
    (5, 3, 5, 200, jnp.float32, 1e4, "onehot"),
    (6, 4, 8, 128, jnp.float32, 2.0, "zero"),  # degenerate w: lse -> log V
    (7, 5, 7, 384, jnp.float32, 2.0, "onehot"),
    (8, 1, 1, 1, jnp.float32, 2.0, "softmax"),  # minimum everything
    (9, 2, 16, 512, jnp.bfloat16, 1e4, "softmax"),
]


def _check(seed, k, b, v, dtype, logit_scale, w_mode):
    case = loss_case(seed, k, b, v, dtype=dtype, logit_scale=logit_scale, w_mode=w_mode)
    assert_loss_grad_parity("ensemble_kl", case, temperature=4.0)
    assert_loss_grad_parity("ensemble_kl", case, temperature=1.0)
    for weighted, stop in ((True, False), (True, True), (False, False)):
        assert_loss_grad_parity(
            "ghm_ce", case, weighted=weighted, stop_difficulty_grad=stop
        )


@pytest.mark.parametrize("seed,k,b,v,dtype,logit_scale,w_mode", EDGE_CASES)
def test_loss_grad_parity_edge_cases(seed, k, b, v, dtype, logit_scale, w_mode):
    _check(seed, k, b, v, dtype, logit_scale, w_mode)


@pytest.mark.parametrize("seed", range(8))
def test_loss_grad_parity_random_sweep(seed):
    """Seeded stand-in for the Hypothesis draw: geometry derived from the
    seed so every run covers 8 distinct (K, B, V) boxes around the tile
    boundaries."""
    k = 1 + seed % 5
    b = 1 + (3 * seed) % 17
    v = 1 + (97 * (seed + 1)) % 700
    dtype = jnp.bfloat16 if seed % 3 == 0 else jnp.float32
    w_mode = ("softmax", "onehot", "zero")[seed % 3]
    _check(100 + seed, k, b, v, dtype, 2.0, w_mode)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        k=st.integers(1, 6),
        b=st.integers(1, 21),
        v=st.integers(1, 700),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
        logit_scale=st.sampled_from([2.0, 1e4]),
        w_mode=st.sampled_from(["softmax", "onehot", "zero"]),
    )
    def test_loss_grad_parity_hypothesis(seed, k, b, v, dtype, logit_scale, w_mode):
        _check(seed, k, b, v, dtype, logit_scale, w_mode)

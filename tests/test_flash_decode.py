"""Paged flash-decode kernel tests.

 * the blocked-jnp ref twin vs the dense ``_sdpa_small`` decode math, with
   the SAME cache contents viewed through pages (GQA × window × softcap —
   the acceptance feature matrix);
 * the Pallas kernel body (interpret mode) vs the ref twin;
 * model-level: ``attn_decode`` over a paged cache matches ``attn_decode``
   over a dense cache holding identical keys/values;
 * the inference-only contract: differentiating flash_decode raises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.kernels.flash_decode import flash_decode, flash_decode_pallas, flash_decode_ref
from repro.models.attention import (
    attn_decode,
    init_attention,
    init_cache,
    init_paged_cache,
)

CASES = [
    ({"h": 4, "kh": 2}, 0, 0.0, 32, 8),  # GQA, full attention
    ({"h": 4, "kh": 4}, 0, 20.0, 32, 16),  # MHA + softcap
    ({"h": 8, "kh": 2}, 10, 0.0, 10, 4),  # GQA + sliding-window ring
    ({"h": 4, "kh": 1}, 16, 30.0, 16, 8),  # MQA + window + softcap
]
IDS = ["gqa", "softcap", "window", "mqa-window-softcap"]


def _mk_paged(rng, b, heads, window, softcap, cl, ps, extra_pages=4):
    """Random pages + a disjoint per-row page table + positions."""
    h, kh = heads["h"], heads["kh"]
    hd = 16
    w = -(-cl // ps)
    n_pages = b * w + extra_pages
    k_pages = jnp.asarray(rng.randn(n_pages, ps, kh, hd).astype(np.float32))
    v_pages = jnp.asarray(rng.randn(n_pages, ps, kh, hd).astype(np.float32))
    q = jnp.asarray(rng.randn(b, h, hd).astype(np.float32))
    table = jnp.asarray(rng.permutation(n_pages)[: b * w].reshape(b, w), jnp.int32)
    hi = 3 * cl if window else cl
    pos = jnp.asarray(rng.randint(0, hi, size=b), jnp.int32)
    return q, k_pages, v_pages, table, pos


def _dense_view(k_pages, table, cl):
    """Materialize each row's logical cache from its pages: (B, cl, KH, hd)."""
    ps = k_pages.shape[1]
    w = table.shape[1]
    flat = jnp.reshape(k_pages[table], (table.shape[0], w * ps, *k_pages.shape[2:]))
    return flat[:, :cl]


def _sdpa_oracle(q, k, v, pos, window, softcap, cl):
    """The dense attn_decode masking + softmax math, unbatched-reference."""
    b, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    posv = np.asarray(pos)
    ring = np.arange(cl)[None, :]
    p = posv[:, None]
    if window > 0:
        slot = posv % cl
        wrap = (p // cl) * cl
        k_pos = np.where(ring <= slot[:, None], wrap + ring, wrap - cl + ring)
        valid = (k_pos >= 0) & (k_pos <= p) & (k_pos > p - window)
    else:
        valid = ring <= p
    qn = np.asarray(q).reshape(b, kh, g, hd)
    s = np.einsum("bkgd,bskd->bkgs", qn, np.asarray(k)) / np.sqrt(hd)
    if softcap > 0:
        s = np.tanh(s / softcap) * softcap
    s = np.where(valid[:, None, None, :], s, -1e30)
    pr = np.exp(s - s.max(-1, keepdims=True))
    pr = pr / pr.sum(-1, keepdims=True)
    return np.einsum("bkgs,bskd->bkgd", pr, np.asarray(v)).reshape(b, h, hd)


@pytest.mark.parametrize("heads,window,softcap,cl,ps", CASES, ids=IDS)
def test_ref_matches_dense_sdpa_math(heads, window, softcap, cl, ps):
    """The paged ref twin is the dense decode attention seen through the
    page-table indirection (acceptance criterion's CPU arm)."""
    rng = np.random.RandomState(0)
    q, k_pages, v_pages, table, pos = _mk_paged(rng, 3, heads, window, softcap, cl, ps)
    got = flash_decode_ref(
        q, k_pages, v_pages, table, pos, window=window, softcap=softcap, cache_len=cl
    )
    want = _sdpa_oracle(
        q, _dense_view(k_pages, table, cl), _dense_view(v_pages, table, cl),
        pos, window, softcap, cl,
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("heads,window,softcap,cl,ps", CASES, ids=IDS)
def test_kernel_interpret_matches_ref(heads, window, softcap, cl, ps):
    """Pallas kernel body (interpreter) vs the blocked-jnp twin — the
    kernel-vs-ref parity pin for interpret mode."""
    rng = np.random.RandomState(1)
    q, k_pages, v_pages, table, pos = _mk_paged(rng, 2, heads, window, softcap, cl, ps)
    ref = flash_decode_ref(
        q, k_pages, v_pages, table, pos, window=window, softcap=softcap, cache_len=cl
    )
    ker = flash_decode_pallas(
        q, k_pages, v_pages, table, pos,
        window=window, softcap=softcap, cache_len=cl, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kw", [{}, {"sliding_window": 8}, {"attn_logit_softcap": 15.0}],
                         ids=["full", "window", "softcap"])
def test_attn_decode_paged_matches_dense(kw):
    """Model-level parity: one attn_decode step over a paged cache vs a dense
    cache holding the SAME keys/values (built by replaying the paged writes
    into the dense ring)."""
    cfg = ModelConfig(
        name="t", family="dense", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, dtype="float32",
        param_dtype="float32", decode_backend="ref", **kw,
    )
    b, max_seq, ps = 3, 32, 8
    params = init_attention(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, 1, cfg.d_model), jnp.float32)
    pos = jnp.asarray([3, 9, 14], jnp.int32)

    dense = init_cache(cfg, b, max_seq, jnp.float32)
    fill_k = jax.random.normal(jax.random.key(2), dense["k"].shape, jnp.float32)
    fill_v = jax.random.normal(jax.random.key(3), dense["v"].shape, jnp.float32)
    dense = {"k": fill_k, "v": fill_v}
    cl = dense["k"].shape[1]
    w = -(-cl // ps)
    paged = init_paged_cache(cfg, b * w, ps, jnp.float32)
    table = jnp.arange(b * w, dtype=jnp.int32).reshape(b, w)
    pad = (-cl) % ps
    as_pages = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(
        b * w, ps, *a.shape[2:]
    )
    paged = {"k_pages": as_pages(fill_k), "v_pages": as_pages(fill_v)}

    out_d, new_d = attn_decode(params, x, cfg, dense, pos)
    out_p, new_p = attn_decode(params, x, cfg, paged, pos, page_table=table)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d), rtol=1e-5, atol=1e-5)
    # the paged write landed exactly where the dense ring write did
    posv = np.asarray(pos)
    slot = posv % cl if cfg.sliding_window > 0 else np.minimum(posv, cl - 1)
    for i in range(b):
        np.testing.assert_allclose(
            np.asarray(new_p["k_pages"][table[i, slot[i] // ps], slot[i] % ps]),
            np.asarray(new_d["k"][i, slot[i]]),
            rtol=1e-6, atol=1e-6,
        )


def test_flash_decode_is_inference_only():
    """The grad-safety guard: flash_decode claims no backward and must fail
    loudly (not silently differentiate a gather graph) if it ever enters a
    loss path — on every backend, including ref."""
    rng = np.random.RandomState(2)
    q, k_pages, v_pages, table, pos = _mk_paged(rng, 2, {"h": 4, "kh": 2}, 0, 0.0, 16, 8)

    def loss(q):
        return jnp.sum(
            flash_decode(q, k_pages, v_pages, table, pos, cache_len=16, backend="ref")
        )

    with pytest.raises(NotImplementedError, match="inference-only"):
        jax.grad(loss)(q)


def test_attn_decode_paged_grad_raises_inference_only():
    """The documented contract holds through the MODEL path, not just the
    raw op: jax.grad through the paged ``attn_decode`` branch (the serve
    engine's decode step) must surface the flash_decode inference-only
    error instead of silently differentiating a gather graph."""
    cfg = ModelConfig(
        name="t", family="dense", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, dtype="float32",
        param_dtype="float32", decode_backend="ref",
    )
    b, ps, w = 2, 8, 4
    params = init_attention(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, 1, cfg.d_model), jnp.float32)
    pos = jnp.asarray([3, 7], jnp.int32)
    paged = init_paged_cache(cfg, b * w, ps, jnp.float32)
    table = jnp.arange(b * w, dtype=jnp.int32).reshape(b, w)

    def loss(p):
        out, _ = attn_decode(p, x, cfg, paged, pos, page_table=table)
        return jnp.sum(out)

    with pytest.raises(NotImplementedError, match="inference-only"):
        jax.grad(loss)(params)

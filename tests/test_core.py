"""Unit + property tests for the paper's core equations (Eq. 2, 5-12)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    adversarial_loss,
    ce_per_sample,
    diversify,
    ensemble_logits,
    generator_loss,
    ghs_loss,
    kl_loss,
    kl_per_sample,
    make_logits_all,
    normalize_weights,
    sample_difficulty,
    uniform_weights,
    update_weights,
)

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# losses


@given(st.integers(2, 40), st.integers(2, 8), st.floats(0.5, 8.0))
@settings(**SETTINGS)
def test_kl_properties(c, b, temp):
    key = jax.random.key(c * 100 + b)
    p = jax.random.normal(key, (b, c)) * 2
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, c)) * 2
    kl = kl_per_sample(p, q, temp)
    assert np.all(np.asarray(kl) >= -1e-5)  # KL non-negative
    np.testing.assert_allclose(kl_per_sample(p, p, temp), np.zeros(b), atol=1e-5)


@given(st.integers(2, 30), st.integers(1, 10))
@settings(**SETTINGS)
def test_sample_difficulty_in_unit_interval(c, b):
    logits = jax.random.normal(jax.random.key(b), (b, c)) * 5
    labels = jax.random.randint(jax.random.key(b + 1), (b,), 0, c)
    d = np.asarray(sample_difficulty(logits, labels))
    assert np.all(d >= 0) and np.all(d <= 1)


def test_ghs_loss_equals_plain_ce_when_disabled():
    logits = jax.random.normal(jax.random.key(0), (8, 10))
    labels = jnp.arange(8) % 10
    plain = float(jnp.mean(ce_per_sample(logits, labels)))
    assert abs(float(ghs_loss(logits, labels, use_ghs=False)) - plain) < 1e-6
    assert float(ghs_loss(logits, labels, use_ghs=True)) <= plain + 1e-6


def test_adversarial_loss_sign():
    """L_A = −KL ⇒ more disagreement ⇒ more negative loss."""
    t = jax.random.normal(jax.random.key(0), (4, 10)) * 3
    close = t + 0.01
    far = -t
    assert float(adversarial_loss(t, far)) < float(adversarial_loss(t, close))


# ---------------------------------------------------------------------------
# ensemble & weights


def test_ensemble_logits_weighted_sum():
    la = jax.random.normal(jax.random.key(0), (3, 5, 7))
    w = jnp.asarray([0.5, 0.3, 0.2])
    want = 0.5 * la[0] + 0.3 * la[1] + 0.2 * la[2]
    np.testing.assert_allclose(ensemble_logits(la, w), want, rtol=1e-6)


@given(st.lists(st.floats(-3, 3), min_size=2, max_size=16))
@settings(**SETTINGS)
def test_normalize_weights_simplex(ws):
    w = normalize_weights(jnp.asarray(ws, jnp.float32))
    w = np.asarray(w)
    assert np.all(w >= 0) and np.all(w <= 1)
    clipped_sum = np.clip(np.asarray(ws, np.float32), 0, 1).sum()
    if clipped_sum > 1e-6:  # non-degenerate: must land on the simplex
        np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)


@given(st.integers(2, 8), st.floats(0.001, 0.2))
@settings(**SETTINGS)
def test_update_weights_stays_on_simplex(n, mu):
    la = jax.random.normal(jax.random.key(n), (n, 16, 6)) * 2
    labels = jax.random.randint(jax.random.key(n + 1), (16,), 0, 6)
    w = uniform_weights(n)
    for _ in range(3):
        w = update_weights(w, la, labels, mu)
    w = np.asarray(w)
    assert np.all(w >= -1e-7) and np.all(w <= 1 + 1e-7)
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)


def test_update_weights_kernel_backend_matches_ref():
    """The fused Eq. 11/12 step (ghm_ce weighted=False + the kernel's w
    cotangent) must follow the same trajectory as the jnp ref path."""
    n, b, c = 3, 16, 6
    la = jax.random.normal(jax.random.key(0), (n, b, c)) * 2
    labels = jax.random.randint(jax.random.key(1), (b,), 0, c)
    w_ref = w_ker = uniform_weights(n)
    for _ in range(3):
        w_ref = update_weights(w_ref, la, labels, 0.05, backend="ref")
        w_ker = update_weights(w_ker, la, labels, 0.05, backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(w_ref), np.asarray(w_ker), atol=1e-6)


def test_update_weights_upweights_better_client():
    """Client 0 predicts labels perfectly, client 1 is anti-correlated —
    Eq. 12 must move weight toward client 0."""
    b, c = 64, 5
    labels = jnp.arange(b) % c
    good = jax.nn.one_hot(labels, c) * 10.0
    bad = jax.nn.one_hot((labels + 1) % c, c) * 10.0
    la = jnp.stack([good, bad])
    w = uniform_weights(2)
    for _ in range(5):
        w = update_weights(w, la, labels, 0.05)
    assert float(w[0]) > float(w[1])


# ---------------------------------------------------------------------------
# DHS (Eq. 9-10)


def test_diversify_perturbation_norm_and_shape():
    def apply_fn(p, x):
        return jnp.tanh(x.reshape(x.shape[0], -1) @ p)

    p0 = jax.random.normal(jax.random.key(0), (12, 4))
    logits_all_fn = make_logits_all([apply_fn])
    x = jax.random.normal(jax.random.key(1), (6, 2, 2, 3))
    eps = 8 / 255
    x2 = diversify(logits_all_fn, (p0,), uniform_weights(1), x, jax.random.key(2), eps)
    assert x2.shape == x.shape
    delta = np.asarray(x2 - x).reshape(6, -1)
    norms = np.linalg.norm(delta, axis=1)
    np.testing.assert_allclose(norms, eps, rtol=1e-3)  # ε-normalized step


def test_diversify_randomness_differs_by_key():
    def apply_fn(p, x):
        return x.reshape(x.shape[0], -1) @ p

    p0 = jax.random.normal(jax.random.key(0), (12, 4))
    fn = make_logits_all([apply_fn])
    x = jax.random.normal(jax.random.key(1), (4, 2, 2, 3))
    a = diversify(fn, (p0,), uniform_weights(1), x, jax.random.key(2), 0.1)
    b = diversify(fn, (p0,), uniform_weights(1), x, jax.random.key(3), 0.1)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_generator_loss_composition():
    ens = jax.random.normal(jax.random.key(0), (8, 10)) * 2
    srv = jax.random.normal(jax.random.key(1), (8, 10)) * 2
    y = jnp.arange(8) % 10
    base = float(generator_loss(ens, srv, y, use_ghs=True, use_adv=False))
    with_adv = float(generator_loss(ens, srv, y, beta=1.0, use_ghs=True, use_adv=True))
    adv = float(adversarial_loss(ens, srv))
    np.testing.assert_allclose(with_adv, base + adv, rtol=1e-5)

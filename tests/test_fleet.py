"""Serving-fleet tests: the prefill/decode worker split, the KV handoff,
and the multi-replica router.

 * disagg config validation (paged-only handoff; pure-SSM archs cannot
   disaggregate because their state degrades to the dense layout);
 * staging-pool accounting across a prefill->adopt handoff (backpressure
   pages are donated back exactly when the decode worker adopts);
 * fleet == single-engine greedy token parity on identical request streams
   — N>=2 replicas including a disaggregated pair, bitwise token equality
   against the single colocated ServeEngine (which itself runs the same
   prefill->handoff->adopt path, so parity is structural);
 * randomized router invariants: no request lost or duplicated across
   replicas, per-replica pool audits balance on every transition, and an
   eviction on one replica cannot touch another replica's pages;
 * requeue-on-defer: a queue head blocked on its routed replica moves to an
   idle replica that can admit it immediately;
 * queue wait vs service time split on deferred admissions;
 * shard_engine_state specs (explicit mesh_axes — no mesh context needed);
 * 8-virtual-device lane (skipped below 8 devices; CI forces them with
   XLA_FLAGS=--xla_force_host_platform_device_count=8): fleet-mesh
   topology, sharded-engine parity, and the routed sharded disagg fleet.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import init_lm
from repro.serve import (
    ContinuousScheduler,
    DecodeWorker,
    EngineConfig,
    FleetRouter,
    ManualClock,
    PrefillWorker,
    Request,
    ServeEngine,
    staggered_stream,
)
from repro.sharding import shard_engine_state

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
    "(set before jax import; the fleet-smoke CI lane does)",
)


def _mk(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, scan_layers=False,
        remat=False, dtype="float32", param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def _stream(cfg, n, seed=3):
    # the shared staggered-stream helper's defaults ARE this file's
    # historical draw order — the tokens these tests pin depend on it
    return staggered_stream(cfg.vocab_size, n, seed=seed)


_ECFG = dict(
    max_slots=2, max_seq=48, max_new=8, decode_chunk=3, prefill_bucket=8,
    page_size=8,
)


# ---------------------------------------------------------------------------
# config validation


def test_disagg_requires_paged_layout():
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(kv_layout="dense", disagg=True)
    # paged+disagg constructs fine
    assert EngineConfig(disagg=True).disagg


def test_disagg_rejects_pure_ssm():
    """A pure-SSM arch has no KV pages; its engine state silently degrades
    to the dense layout, so a disaggregated pair must be rejected at the
    ENGINE (the config alone cannot know the arch)."""
    cfg = _mk(family="ssm", ssm_kind="mamba", d_ff=0, num_kv_heads=4)
    params = init_lm(cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="no attention"):
        ServeEngine(cfg, params, EngineConfig(disagg=True, **_ECFG))


def test_router_needs_engines():
    with pytest.raises(ValueError, match="at least one"):
        FleetRouter([])


# ---------------------------------------------------------------------------
# the handoff itself


def test_handoff_staging_accounting():
    """A sealed prefill burst reserves staging pages on the SOURCE pool
    (backpressure on in-flight handoffs) and donates them back exactly when
    the decode worker adopts — ids never cross pools."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    ecfg = EngineConfig(**_ECFG)
    pw = PrefillWorker(cfg, params, ecfg)
    dw = DecodeWorker(cfg, params, ecfg, stats=pw.stats)
    prompts = [np.arange(5, dtype=np.int32), np.arange(7, dtype=np.int32)]
    h = pw.prefill_group([(p, 4) for p in prompts])
    assert h.n == 2 and h.n_alloc == 1  # 8-token bucket = 1 page of 8
    assert pw.staging.pages_in_use == 2  # reserved while in flight
    assert dw.pool.pages_in_use == 0  # nothing landed yet
    slots = dw.adopt(h)
    assert pw.staging.pages_in_use == 0  # donated on adoption
    assert dw.pool.pages_in_use == 2
    assert sorted(len(dw.pool.owned(s)) for s in slots) == [1, 1]
    # the decode half actually decodes what the prefill half sealed
    dw.decode_chunk()
    active, n_out = dw.sync()
    assert all(n_out[s] >= 1 for s in slots)


def test_adopt_atomic_on_full_pool():
    """A burst whose sealed pages outsize the adopting pool raises BEFORE
    any slot or page moves — the handoff stays intact for a retry."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    ecfg = EngineConfig(
        max_slots=2, max_seq=32, max_new=4, decode_chunk=4, prefill_bucket=16,
        page_size=8, pool_pages=4,
    )
    pw = PrefillWorker(cfg, params, ecfg)
    dw = DecodeWorker(cfg, params, ecfg, stats=pw.stats)
    big = np.arange(20, dtype=np.int32)  # buckets to 32 tokens = 4 pages
    h1 = pw.prefill_group([(big, 4)])
    dw.adopt(h1)  # fills the pool
    pw2 = PrefillWorker(cfg, params, ecfg)
    h2 = pw2.prefill_group([(big, 4)])
    with pytest.raises(RuntimeError, match="cannot adopt"):
        dw.adopt(h2)
    assert len(dw.free_slots) == 1  # no slot consumed by the failed adopt
    assert pw2.staging.pages_in_use == 4  # handoff still staged, retryable
    # drain the first request; the SAME handoff now lands
    for _ in range(4):
        dw.decode_chunk()
        active, n_out = dw.sync()
        if not active.any():
            break
    (slot,) = [s for s in range(ecfg.max_slots) if s not in dw.free_slots]
    dw.fetch(slot, int(n_out[slot]))
    dw.adopt(h2)
    assert pw2.staging.pages_in_use == 0


# ---------------------------------------------------------------------------
# fleet == single engine parity


def _fleet(cfg, params, n, disagg_first=False, **over):
    kw = dict(_ECFG)
    kw.update(over)
    engines = []
    for i in range(n):
        ecfg = EngineConfig(disagg=disagg_first and i == 0, **kw)
        engines.append(ServeEngine(cfg, params, ecfg))
    return engines


@pytest.mark.parametrize("n,disagg", [(2, False), (2, True), (3, True)],
                         ids=["n2", "n2-disagg", "n3-disagg"])
def test_fleet_matches_single_engine_tokens(n, disagg):
    """The routed fleet (N replicas, optionally one an explicitly
    disaggregated pair) produces bitwise-identical greedy tokens to ONE
    colocated ServeEngine on the same staggered ragged request stream."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    reqs = _stream(cfg, 9)
    single = ServeEngine(cfg, params, EngineConfig(**_ECFG))
    ref = {c.rid: c.tokens for c in
           ContinuousScheduler(single, clock=ManualClock(tick=0.2)).run(reqs)}
    router = FleetRouter(_fleet(cfg, params, n, disagg_first=disagg),
                         clock=ManualClock(tick=0.2))
    comps = router.run(reqs)
    assert sorted(c.rid for c in comps) == sorted(ref)
    for c in comps:
        np.testing.assert_array_equal(c.tokens, ref[c.rid])
    # the fleet actually spread load (least-loaded routing, 9 reqs, N pools)
    assert len({c.replica for c in comps}) > 1
    if disagg:
        assert router.engines[0].stats["handoffs"] > 0


def test_fleet_dense_layout_matches_single():
    """The router's load unit degrades to slot counts in the dense layout —
    parity and conservation must hold there too."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    reqs = _stream(cfg, 7, seed=9)
    single = ServeEngine(cfg, params, EngineConfig(kv_layout="dense", **_ECFG))
    ref = {c.rid: c.tokens for c in
           ContinuousScheduler(single, clock=ManualClock(tick=0.2)).run(reqs)}
    comps = FleetRouter(
        _fleet(cfg, params, 2, kv_layout="dense"), clock=ManualClock(tick=0.2)
    ).run(reqs)
    assert sorted(c.rid for c in comps) == sorted(ref)
    for c in comps:
        np.testing.assert_array_equal(c.tokens, ref[c.rid])


def test_router_prefix_affinity_routes_hot_requests():
    """With per-replica prefix caches, the router sends a request wherever
    its prefix is RESIDENT: the first serve of a hot prompt lands by load,
    every re-serve lands on the replica already holding its pages (affinity
    leads the routing key; load only breaks ties), and the warm replica
    splices instead of re-prefilling."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    rng = np.random.RandomState(21)
    hot = rng.randint(0, cfg.vocab_size, size=16).astype(np.int32)
    cold = [rng.randint(0, cfg.vocab_size, size=16).astype(np.int32) for _ in range(2)]
    # hot arrives first and keeps re-arriving; cold traffic interleaves so
    # plain least-loaded routing WOULD bounce the hot prompt between replicas
    prompts = [hot, cold[0], hot, cold[1], hot, hot]
    reqs = [
        Request(rid=i, tokens=p, max_new_tokens=3, arrival=1.5 * i)
        for i, p in enumerate(prompts)
    ]
    engines = _fleet(cfg, params, 2, prefix_cache=True)
    router = FleetRouter(engines, clock=ManualClock(tick=0.2))
    comps = {c.rid: c for c in router.run(reqs)}
    assert len(comps) == len(reqs)
    warm = comps[0].replica  # wherever the first hot serve landed
    assert all(comps[r].replica == warm for r in (2, 4, 5)), "hot prompt bounced"
    assert router.stats["affinity_hits"] >= 3
    assert engines[warm].stats["spliced_admissions"] >= 3
    assert engines[1 - warm].stats["spliced_admissions"] == 0


# ---------------------------------------------------------------------------
# randomized router invariants


class _Audit:
    """Delegating per-replica wrapper asserting slot and page hygiene on
    every transition, and that transitions on THIS replica never move
    another replica's pool (cross-replica isolation)."""

    def __init__(self, inner, peers_fn):
        self._e = inner
        self._peers = peers_fn
        self.in_use = set()

    def __getattr__(self, name):
        return getattr(self._e, name)

    def _pool_snapshot(self, eng):
        pool = eng.pool
        return (
            pool.free_pages,
            {s: tuple(pool.owned(s)) for s in range(eng.ecfg.max_slots)},
        )

    def _check(self):
        pool = self._e.pool
        owned = [p for s in range(self._e.ecfg.max_slots) for p in pool.owned(s)]
        assert len(owned) == len(set(owned)), "page double-booked"
        assert pool.free_pages + len(owned) == pool.n_pages, "free-list leak"
        assert all(not pool.owned(s) for s in self._e.free_slots)

    def admit_many(self, requests):
        peers_before = [self._pool_snapshot(p) for p in self._peers(self)]
        slots = self._e.admit_many(requests)
        assert len(set(slots)) == len(slots)
        for slot in slots:
            assert slot not in self.in_use, f"slot {slot} double-booked"
            self.in_use.add(slot)
        self._check()
        assert peers_before == [self._pool_snapshot(p) for p in self._peers(self)], (
            "admission on one replica moved another replica's pool"
        )
        return slots

    def decode_chunk(self):
        self._e.decode_chunk()
        self._check()

    def fetch(self, slot, n_out):
        assert slot in self.in_use
        self.in_use.discard(slot)
        peers_before = [self._pool_snapshot(p) for p in self._peers(self)]
        toks = self._e.fetch(slot, n_out)
        self._check()
        assert peers_before == [self._pool_snapshot(p) for p in self._peers(self)], (
            "eviction on one replica touched another replica's pages"
        )
        return toks


def test_router_randomized_invariants():
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    engines = _fleet(cfg, params, 3, max_slots=2)
    audits = [None] * len(engines)
    peers = lambda a: [x._e for x in audits if x is not a]
    for i, eng in enumerate(engines):
        audits[i] = _Audit(eng, peers)
    reqs = _stream(cfg, 17, seed=11)
    comps = FleetRouter(audits, clock=ManualClock(tick=0.3)).run(reqs)
    # no request lost or duplicated across replicas
    assert sorted(c.rid for c in comps) == sorted(r.rid for r in reqs)
    by_rid = {c.rid: c for c in comps}
    for r in reqs:
        c = by_rid[r.rid]
        assert len(c.tokens) == r.max_new_tokens
        assert c.admitted >= r.arrival and c.finished >= c.admitted
        assert 0 <= c.replica < len(engines)
    for a, eng in zip(audits, engines):
        assert not a.in_use
        assert sorted(eng.free_slots) == list(range(eng.ecfg.max_slots))
        assert eng.pool.pages_in_use == 0 and eng.pool.free_pages == eng.pool.n_pages
        assert not bool(np.asarray(eng._state.active).any())
    assert sum(e.stats["evicted"] for e in engines) == len(reqs)
    assert sum(e.stats["admitted"] for e in engines) == len(reqs)


def test_router_requeues_blocked_head_to_idle_replica():
    """Arrival-time routing goes stale: rid=2 lands on replica 0 by the
    load tiebreak, but replica 0 is pinned by a long-budget resident while
    replica 1 drains quickly — the blocked head must move (requeue-on-defer)
    and complete on replica 1 instead of waiting out replica 0."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    kw = dict(max_slots=1, max_seq=48, max_new=16, decode_chunk=2,
              prefill_bucket=8, kv_layout="dense")
    engines = [ServeEngine(cfg, params, EngineConfig(**kw)) for _ in range(2)]
    prompt = np.arange(6, dtype=np.int32)
    reqs = [
        Request(rid=0, tokens=prompt, max_new_tokens=16),  # -> replica 0, slow
        Request(rid=1, tokens=prompt, max_new_tokens=2),   # -> replica 1, fast
        Request(rid=2, tokens=prompt, max_new_tokens=2),   # -> replica 0 queue
    ]
    router = FleetRouter(engines, clock=ManualClock(tick=0.1))
    comps = {c.rid: c for c in router.run(reqs)}
    assert comps[0].replica == 0 and comps[1].replica == 1
    assert router.stats["requeued"] == 1
    assert comps[2].replica == 1  # moved off the blocked replica
    assert comps[2].finished < comps[0].finished


def test_router_fail_fast_when_no_replica_can_ever_admit():
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    engines = _fleet(cfg, params, 2, max_seq=32, max_new=4, decode_chunk=4,
                     prefill_bucket=8, pool_pages=2)
    big = Request(rid=0, tokens=np.arange(26, dtype=np.int32), max_new_tokens=4)
    with pytest.raises(RuntimeError, match="never be admitted"):
        FleetRouter(engines, clock=ManualClock()).run([big])


# ---------------------------------------------------------------------------
# queue wait vs service


def test_queue_wait_separates_arrival_from_admission():
    """A deferred request's Completion records admission separately from
    arrival: queue_wait + service == latency, and the deferred request (the
    pool fits one lifetime bill at a time) shows a strictly positive wait
    while the first admit's wait stays ~the clock tick."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    eng = ServeEngine(
        cfg, params,
        EngineConfig(max_slots=2, max_seq=32, max_new=16, decode_chunk=4,
                     prefill_bucket=8, page_size=8, pool_pages=4),
    )
    reqs = [Request(rid=i, tokens=np.arange(8, dtype=np.int32), max_new_tokens=16)
            for i in range(2)]
    comps = {c.rid: c for c in
             ContinuousScheduler(eng, clock=ManualClock(tick=0.1)).run(reqs)}
    for c in comps.values():
        assert c.queue_wait >= 0 and c.service > 0
        np.testing.assert_allclose(c.queue_wait + c.service, c.latency)
    # rid=1 could not admit until rid=0 fully drained: its wait spans rid=0's
    # service, so it dominates rid=0's (near-zero) wait
    assert comps[1].queue_wait > comps[0].queue_wait
    assert comps[1].queue_wait > comps[0].service / 2


# ---------------------------------------------------------------------------
# engine-state sharding specs (no mesh needed: explicit axes)


def test_shard_engine_state_specs():
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, EngineConfig(**_ECFG))
    specs = shard_engine_state(eng._state, mesh_axes={"data": 1, "model": 2})
    # paged pools shard along kv-heads (2 % 2 == 0), nothing else
    for key in eng._state.kv:
        assert specs.kv[key]["k_pages"] == P(None, None, None, "model", None)
        assert specs.kv[key]["v_pages"] == P(None, None, None, "model", None)
    # slot bookkeeping is replicated — the host mutates it by slot id
    assert specs.page_table == P(None, None)
    assert specs.pos == P(None)
    assert specs.out == P(None, None)
    # indivisible heads fall back to replication instead of erroring
    specs3 = shard_engine_state(eng._state, mesh_axes={"data": 1, "model": 3})
    for key in eng._state.kv:
        assert specs3.kv[key]["k_pages"] == P(None, None, None, None, None)
    # no axes -> fully replicated
    specs0 = shard_engine_state(eng._state, mesh_axes={})
    assert specs0.pos == P()


def test_fleet_mesh_rejects_ragged_split():
    from repro.launch.mesh import make_fleet_mesh

    with pytest.raises(ValueError, match="divide"):
        make_fleet_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError, match=">= 1"):
        make_fleet_mesh(0)


# ---------------------------------------------------------------------------
# 8-virtual-device lane (fleet-smoke CI forces host devices pre-import)


@needs_8_devices
def test_fleet_mesh_topology():
    from repro.launch.mesh import disagg_submeshes, make_fleet_mesh, replica_meshes

    fleet = make_fleet_mesh(2)
    assert fleet.axis_names == ("replica", "data", "model")
    assert dict(fleet.shape) == {"replica": 2, "data": 1, "model": 4}
    subs = replica_meshes(fleet)
    assert len(subs) == 2
    seen = set()
    for sub in subs:
        assert sub.axis_names == ("data", "model")
        assert dict(sub.shape) == {"data": 1, "model": 4}
        ids = {d.id for d in sub.devices.flat}
        assert not ids & seen  # replicas are physically disjoint
        seen |= ids
        pmesh, dmesh = disagg_submeshes(sub)
        pids = {d.id for d in pmesh.devices.flat}
        dids = {d.id for d in dmesh.devices.flat}
        assert not pids & dids and pids | dids == ids
    # single-device replica colocates rather than failing
    one = replica_meshes(make_fleet_mesh(8))[0]
    pm, dm = disagg_submeshes(one)
    assert pm is dm is one


@needs_8_devices
def test_sharded_engine_matches_meshless_tokens():
    """One engine sharded over a ("data", "model") submesh produces the
    same greedy tokens as the meshless engine — the tensor-parallel split
    must be numerically invisible at the argmax."""
    from repro.launch.mesh import make_fleet_mesh, replica_meshes

    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    reqs = _stream(cfg, 5, seed=13)
    ref = {c.rid: c.tokens for c in ContinuousScheduler(
        ServeEngine(cfg, params, EngineConfig(**_ECFG)), clock=ManualClock(tick=0.2)
    ).run(reqs)}
    sub = replica_meshes(make_fleet_mesh(2))[0]
    eng = ServeEngine(cfg, params, EngineConfig(**_ECFG), mesh=sub)
    comps = ContinuousScheduler(eng, clock=ManualClock(tick=0.2)).run(reqs)
    assert sorted(c.rid for c in comps) == sorted(ref)
    for c in comps:
        np.testing.assert_array_equal(c.tokens, ref[c.rid])


@needs_8_devices
def test_router_sharded_disagg_fleet_parity():
    """The acceptance pin: a 2-replica routed fleet on disjoint mesh slices
    — one replica a disaggregated prefill/decode pair on its OWN submesh
    halves — yields bitwise-identical greedy tokens to the single colocated
    meshless ServeEngine on the same request stream."""
    from repro.launch.mesh import disagg_submeshes, make_fleet_mesh, replica_meshes

    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    reqs = _stream(cfg, 8, seed=17)
    ref = {c.rid: c.tokens for c in ContinuousScheduler(
        ServeEngine(cfg, params, EngineConfig(**_ECFG)), clock=ManualClock(tick=0.2)
    ).run(reqs)}
    subs = replica_meshes(make_fleet_mesh(2))
    pmesh, dmesh = disagg_submeshes(subs[0])
    engines = [
        ServeEngine(cfg, params, EngineConfig(disagg=True, **_ECFG),
                    mesh=dmesh, prefill_mesh=pmesh),
        ServeEngine(cfg, params, EngineConfig(**_ECFG), mesh=subs[1]),
    ]
    router = FleetRouter(engines, clock=ManualClock(tick=0.2))
    comps = router.run(reqs)
    assert sorted(c.rid for c in comps) == sorted(ref)
    for c in comps:
        np.testing.assert_array_equal(c.tokens, ref[c.rid])
    assert len({c.replica for c in comps}) == 2  # both replicas served
    assert engines[0].stats["handoffs"] > 0  # the disagg pair actually ran

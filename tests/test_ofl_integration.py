"""End-to-end OFL integration: the paper's pipeline at miniature scale.

Validation targets are the paper's qualitative claims (scaled):
  * Co-Boosting lifts the server far above its random init;
  * the learned ensemble weights leave the uniform simplex point;
  * FedAvg on non-IID shards underperforms the distilled server
    (Table 1's headline ordering), using MLP clients for CPU speed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.train import OFLConfig
from repro.core import (
    default_image_setup,
    fedavg,
    run_coboosting,
    uniform_weights,
)
from repro.data import make_synth_images
from repro.fed import build_market, evaluate_cnn, market_eval_fn
from repro.models.cnn import cnn_apply, init_cnn

# full pipeline at miniature scale — minutes of wall time, so excluded from
# the default tier-1 lane (run with `pytest -m ""` or `-m slow`)
pytestmark = pytest.mark.slow

CLASSES = 5
SHAPE = (16, 16, 3)


@pytest.fixture(scope="module")
def market():
    x, y = make_synth_images(0, CLASSES, 100, SHAPE)
    tx, ty = make_synth_images(1, CLASSES, 30, SHAPE)
    cfg = OFLConfig(
        num_clients=3, alpha=0.3, local_epochs=15, local_batch_size=32,
        epochs=14, gen_iters=5, batch_size=32, latent_dim=16, buffer_batches=2,
        server_lr=0.05,
    )
    applies, params, sizes, _ = build_market(
        0, x, y, cfg, CLASSES, archs=["mlp", "mlp", "mlp"]
    )
    return cfg, applies, params, sizes, (x, y, tx, ty)


def test_clients_learned_their_shards(market):
    cfg, applies, params, sizes, (x, y, tx, ty) = market
    # each client must beat chance on the global test set (they saw a shard)
    for ap, p in zip(applies, params):
        acc = evaluate_cnn(ap, p, tx, ty)
        assert acc > 1.5 / CLASSES, acc


def test_coboosting_end_to_end(market):
    cfg, applies, params, sizes, (x, y, tx, ty) = market
    server_apply = partial(cnn_apply, "mlp")
    server_params = init_cnn(jax.random.key(99), "mlp", CLASSES, SHAPE)
    eval_fn = market_eval_fn(applies, params, server_apply, tx, ty)
    pre = eval_fn(server_params, uniform_weights(3))

    gen_apply, gen_params = default_image_setup(jax.random.key(5), cfg, CLASSES, SHAPE)
    st = run_coboosting(
        applies, params, server_apply, server_params, gen_apply, gen_params,
        cfg, CLASSES, jax.random.key(0), eval_fn=eval_fn, eval_every=cfg.epochs,
    )
    final = st.history[-1]
    # server learned from data-free distillation: clearly above chance and
    # above its (possibly lucky) random init
    assert final["server_acc"] > pre["server_acc"] + 0.05, (pre, final)
    assert final["server_acc"] > 1.4 / CLASSES, final
    # EE moved the weights off the uniform point but kept the simplex
    w = np.asarray(st.weights)
    assert abs(w.sum() - 1) < 1e-4
    assert not np.allclose(w, 1 / 3, atol=1e-3)
    # ensemble at least as good as uniform ensemble (paper: usually better)
    assert final["ensemble_acc"] >= pre["ensemble_acc"] - 0.05


def test_fedavg_below_ensemble_on_noniid(market):
    cfg, applies, params, sizes, (x, y, tx, ty) = market
    avg = fedavg(params, sizes)
    acc_avg = evaluate_cnn(partial(cnn_apply, "mlp"), avg, tx, ty)
    eval_fn = market_eval_fn(applies, params, partial(cnn_apply, "mlp"), tx, ty)
    ens = eval_fn(avg, uniform_weights(3))["ensemble_acc"]
    # the logit ensemble beats naive parameter averaging under non-IID
    assert ens > acc_avg, (ens, acc_avg)

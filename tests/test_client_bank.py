"""ClientBank grouped-ensemble engine: parity with the K-way looped path.

Pins the tentpole contracts:
  * grouped logits == looped logits on randomized heterogeneous markets
    (random arch assignment, random group sizes, singletons,
    all-homogeneous) — the stack comes back in original client order;
    bitwise for matmul archs / singleton groups, one-ULP-scale float
    tolerance where a multi-client conv group rebatches the conv;
  * input gradients through the bank match the loop (DHS / generator path);
  * the stack dtype is normalized to f32 at the ensemble boundary even on
    mixed-dtype markets (a bf16 client next to f32 ones);
  * building the grouped forward traces each apply fn once per GROUP, not
    once per client (the O(#groups) trace-cost claim);
  * ``scan_chunk`` (the memory lever) changes nothing numerically;
  * ``local_train_group`` reproduces per-client ``local_train`` bitwise,
    including partial batches and unequal shard step counts;
  * a fused Co-Boosting epoch run grouped matches the looped run on a
    heterogeneous market (server params, EE weights).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.train import OFLConfig, TrainConfig
from repro.core import default_image_setup, run_coboosting
from repro.core.client_bank import ClientBank, make_ensemble
from repro.core.ensemble import ENSEMBLE_DTYPE
from repro.data import make_synth_images
from repro.fed import build_market, build_market_grouped, local_train, local_train_group
from repro.models.cnn import cnn_apply, init_cnn
from repro.utils import tree_stack
from repro.utils.trees import tree_unstack

pytestmark = pytest.mark.tier1

CLASSES = 5
SHAPE = (8, 8, 3)
ARCH_POOL = ("mlp", "cnn2", "lenet5")


def _market(archs, seed=0):
    applies = [partial(cnn_apply, a) for a in archs]
    params = [
        init_cnn(jax.random.fold_in(jax.random.key(seed), k), a, CLASSES, SHAPE)
        for k, a in enumerate(archs)
    ]
    return applies, params


def _logits_pair(archs, x, **bank_kw):
    applies, params = _market(archs)
    loop_fn, loop_p = make_ensemble(applies, params, impl="looped")
    grp_fn, grp_p = make_ensemble(applies, params, impl="grouped", **bank_kw)
    return loop_fn(loop_p, x), grp_fn(grp_p, x)


# ---------------------------------------------------------------------------
# logits parity


# a multi-client conv group lowers to a batched conv whose accumulation
# order may differ from the per-client conv — tight float tolerance there;
# matmul archs and singleton groups stay bitwise
ATOL = 1e-5


@pytest.mark.parametrize(
    "archs",
    [
        ["mlp"] * 4,                       # all-homogeneous: one group
        ["mlp", "cnn2", "lenet5"],         # all-singleton groups
        ["mlp", "cnn2", "mlp", "cnn2"],    # interleaved (order restore)
        ["cnn2", "mlp", "mlp", "lenet5", "cnn2", "mlp"],
    ],
)
def test_grouped_matches_looped(archs):
    x = jax.random.normal(jax.random.key(7), (4, *SHAPE))
    la, ga = _logits_pair(archs, x)
    assert la.shape == ga.shape == (len(archs), 4, CLASSES)
    np.testing.assert_allclose(np.asarray(la), np.asarray(ga), atol=ATOL)


def test_grouped_matches_looped_bitwise_matmul_archs():
    """Where no conv rebatching is involved the stack is bit-identical."""
    x = jax.random.normal(jax.random.key(7), (4, *SHAPE))
    la, ga = _logits_pair(["mlp"] * 5, x)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(ga))
    la, ga = _logits_pair(["mlp", "cnn2", "lenet5"], x)  # singleton groups
    np.testing.assert_array_equal(np.asarray(la), np.asarray(ga))


def test_grouped_matches_looped_randomized():
    """Hypothesis-style randomized heterogeneous markets (seeded numpy keeps
    it deterministic; hypothesis strategies can't draw jax trees cheaply)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(st.integers(1, 9), st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def check(k, seed):
        rng = np.random.RandomState(seed)
        archs = [ARCH_POOL[i] for i in rng.randint(0, len(ARCH_POOL), size=k)]
        x = jax.random.normal(jax.random.key(seed), (3, *SHAPE))
        la, ga = _logits_pair(archs, x)
        np.testing.assert_allclose(np.asarray(la), np.asarray(ga), atol=ATOL)

    check()


def test_grouped_under_jit_and_grad():
    """Input gradients (the DHS/Eq. 10 and generator paths differentiate the
    ensemble wrt x) agree between the bank and the loop."""
    archs = ["mlp", "cnn2", "mlp", "lenet5"]
    applies, params = _market(archs)
    x = jax.random.normal(jax.random.key(3), (4, *SHAPE))
    loop_fn, loop_p = make_ensemble(applies, params, impl="looped")
    grp_fn, grp_p = make_ensemble(applies, params, impl="grouped")
    gl = jax.jit(jax.grad(lambda xx: jnp.sum(loop_fn(loop_p, xx) ** 2)))(x)
    gg = jax.jit(jax.grad(lambda xx: jnp.sum(grp_fn(grp_p, xx) ** 2)))(x)
    np.testing.assert_allclose(np.asarray(gl), np.asarray(gg), atol=1e-5)


def test_scan_chunk_parity():
    archs = ["mlp"] * 7 + ["cnn2"] * 3
    x = jax.random.normal(jax.random.key(11), (2, *SHAPE))
    base, _ = _logits_pair(archs, x)
    for chunk in (1, 2, 3, 7, 16):
        _, chunked = _logits_pair(archs, x, scan_chunk=chunk)
        np.testing.assert_allclose(np.asarray(base), np.asarray(chunked), atol=ATOL)


# ---------------------------------------------------------------------------
# grouping structure + trace cost


def test_bank_grouping_and_order():
    archs = ["cnn2", "mlp", "mlp", "lenet5", "cnn2", "mlp"]
    applies, params = _market(archs)
    bank, bank_params = ClientBank.build(applies, params)
    assert bank.num_groups == 3
    assert bank.counts == (2, 3, 1)           # first-seen group order
    assert bank.order == (0, 4, 1, 2, 5, 3)   # within-group client order kept
    assert bank.num_clients == 6 and not bank.is_client_ordered
    # params round-trip in original client order
    back = bank.unstack_params(bank_params)
    for p0, p1 in zip(params, back):
        for u, v in zip(jax.tree_util.tree_leaves(p0), jax.tree_util.tree_leaves(p1)):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v))
    # and regroup to the identical stacked layout
    restacked = bank.stack_params(back)
    for u, v in zip(jax.tree_util.tree_leaves(bank_params), jax.tree_util.tree_leaves(restacked)):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))
    for k, a in enumerate(archs):
        assert bank.client_apply(k).args == (a,)


def test_grouped_traces_once_per_group():
    """The O(#groups) trace-cost pin, mirroring the fused-epoch dispatch
    count test: tracing the grouped forward calls each apply fn once per
    GROUP (vmap traces the fn body once), while the looped forward unrolls
    once per CLIENT."""
    archs = ["mlp", "cnn2"] * 4  # K=8, 2 groups
    calls = []

    def counting_apply(arch, p, x):
        calls.append(arch)
        return cnn_apply(arch, p, x)

    applies = [partial(counting_apply, a) for a in archs]
    _, params = _market(archs)
    x = jax.random.normal(jax.random.key(0), (2, *SHAPE))

    grp_fn, grp_p = make_ensemble(applies, params, impl="grouped")
    calls.clear()
    jax.jit(grp_fn)(grp_p, x).block_until_ready()
    assert len(calls) == 2  # once per group, independent of K

    loop_fn, loop_p = make_ensemble(applies, params, impl="looped")
    calls.clear()
    jax.jit(loop_fn)(loop_p, x).block_until_ready()
    assert len(calls) == len(archs)  # the unrolled baseline is O(K)


def test_unknown_callables_fall_back_to_singletons():
    """Apply fns the grouping key can't prove identical degrade to singleton
    groups — still correct, never wrongly merged."""
    archs = ["mlp", "mlp"]
    _, params = _market(archs)
    applies = [lambda p, x: cnn_apply("mlp", p, x), lambda p, x: cnn_apply("mlp", p, x)]
    bank, bank_params = ClientBank.build(applies, params)
    assert bank.num_groups == 2
    x = jax.random.normal(jax.random.key(1), (2, *SHAPE))
    ref = jnp.stack([f(p, x) for f, p in zip(applies, params)])
    np.testing.assert_array_equal(np.asarray(bank.logits_all(bank_params, x)), np.asarray(ref))


# ---------------------------------------------------------------------------
# dtype normalization at the ensemble boundary


def test_mixed_dtype_market_normalizes_to_f32():
    """A bf16 client next to f32 ones: both impls produce the same f32 stack
    (pre-fix, jnp.stack promotion depended on client order)."""
    archs = ["mlp", "mlp", "cnn2"]
    applies, params = _market(archs)
    params[1] = jax.tree_util.tree_map(lambda l: l.astype(jnp.bfloat16), params[1])
    bf16_apply = lambda p, x: cnn_apply("mlp", p, x.astype(jnp.bfloat16))
    applies[1] = bf16_apply
    x = jax.random.normal(jax.random.key(2), (3, *SHAPE))
    for impl in ("looped", "grouped"):
        fn, p = make_ensemble(applies, params, impl=impl)
        la = fn(p, x)
        assert la.dtype == ENSEMBLE_DTYPE == jnp.float32
        # rows are each client's own output, cast — not a promoted mixture
        np.testing.assert_array_equal(
            np.asarray(la[1]), np.asarray(bf16_apply(params[1], x).astype(jnp.float32))
        )


# ---------------------------------------------------------------------------
# grouped local training (build_market_grouped path)


def test_local_train_group_matches_sequential_bitwise():
    rng = np.random.RandomState(0)
    sizes = [37, 64, 19]  # partial batches + unequal step counts
    shards = [
        (rng.randn(n, *SHAPE).astype(np.float32), rng.randint(0, CLASSES, n))
        for n in sizes
    ]
    tc = TrainConfig(optimizer="sgdm", learning_rate=0.01, momentum=0.9,
                     batch_size=16, seed=3)
    apply_fn = partial(cnn_apply, "mlp")
    inits = [
        init_cnn(jax.random.fold_in(jax.random.key(0), k), "mlp", CLASSES, SHAPE)
        for k in range(3)
    ]
    seq = [local_train(apply_fn, p0, x, y, tc, epochs=2) for p0, (x, y) in zip(inits, shards)]
    grp = tree_unstack(local_train_group(apply_fn, tree_stack(inits), shards, tc, epochs=2), 3)
    for a, b in zip(seq, grp):
        for u, v in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


def test_build_market_grouped_matches_build_market():
    cfg = OFLConfig(num_clients=4, local_epochs=2, local_batch_size=16, alpha=0.5)
    archs = ["mlp", "cnn2", "mlp", "cnn2"]
    x, y = make_synth_images(0, CLASSES, 30, SHAPE)
    applies, params, sizes, parts = build_market(0, x, y, cfg, CLASSES, archs=archs)
    bank, bank_params, g_sizes, g_parts = build_market_grouped(0, x, y, cfg, CLASSES, archs=archs)
    assert g_sizes == sizes
    for a, b in zip(parts, g_parts):
        np.testing.assert_array_equal(a, b)
    grouped_clients = bank.unstack_params(bank_params)
    # the cnn2 group trains under a vmapped conv whose grads reassociate
    # (~1e-8); the mlp group stays bitwise (pinned separately above)
    for a, b in zip(params, grouped_clients):
        for u, v in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v), atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end: fused epoch on a heterogeneous market, grouped vs looped


def test_fused_epoch_grouped_matches_looped_hetero():
    """The whole Algorithm 1 loop (generator + DHS + EE + KD) on a mixed-arch
    market: routing the client forwards through the bank must reproduce the
    looped run — same PRNG stream, float-reassociation tolerance only."""
    cfg = OFLConfig(
        num_clients=3, local_epochs=2, local_batch_size=16,
        epochs=4, gen_iters=3, batch_size=8, latent_dim=8, buffer_batches=3,
    )
    x, y = make_synth_images(0, CLASSES, 30, SHAPE)
    archs = ["mlp", "cnn2", "mlp"]
    applies, params, _, _ = build_market(0, x, y, cfg, CLASSES, archs=archs)
    server_apply = partial(cnn_apply, "mlp")

    def run(impl):
        c = dataclasses.replace(cfg, ensemble_impl=impl)
        sp = init_cnn(jax.random.key(99), "mlp", CLASSES, SHAPE)
        gen_apply, gp = default_image_setup(jax.random.key(5), c, CLASSES, SHAPE)
        return run_coboosting(
            applies, params, server_apply, sp, gen_apply, gp, c, CLASSES,
            jax.random.key(0),
        )

    grouped, looped = run("grouped"), run("looped")
    diff = max(
        float(jnp.max(jnp.abs(u - v)))
        for u, v in zip(
            jax.tree_util.tree_leaves(grouped.server_params),
            jax.tree_util.tree_leaves(looped.server_params),
        )
    )
    assert diff < 1e-4
    np.testing.assert_allclose(
        np.asarray(grouped.weights), np.asarray(looped.weights), atol=1e-5
    )

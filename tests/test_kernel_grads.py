"""Differentiable kernel path: custom_vjp grad parity vs the jnp oracles,
backend dispatch rules, and fused-epoch equivalence of the "ref" and
"pallas-interpret" paths.

The VJP contract (repro/kernels/*/ops.py): the Pallas forward returns its
online softmax statistics as residuals and the FUSED PALLAS BACKWARD
produces the cotangents — ``ensemble_kl``: client_logits, student_logits
and w (the student cotangent drives server distillation, Eq. 4; the w
cotangent the EE step, Eq. 12); ``ghm_ce``: client_logits and w (labels are
integer, float0); ``flash_attention``: dq/dk/dv rebuilt from the saved lse
with no score-block re-materialization. ``backend="ref"`` bypasses the
custom_vjp — plain autodiff of the jnp oracle is the parity baseline.

Shared fixtures live in tests/grad_harness.py; randomized/adversarial
shapes in tests/test_kernel_grads_property.py (slow lane).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from grad_harness import (
    INTERP,
    METHODS,
    TOL,
    assert_loss_grad_parity,
    assert_method_backend_parity,
    assert_tree_close,
    build_tiny_market,
    check_kernel_grads,
    loss_case,
)
from repro.kernels import (
    ensemble_kl,
    ensemble_kl_ref,
    flash_attention,
    ghm_ce,
    ghm_ce_ref,
    resolve_backend,
)
from repro.kernels.flash_attention.ref import flash_attention_ref

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# dispatch rules


def test_dispatch_auto_never_interprets_off_tpu():
    assert resolve_backend("auto") in ("pallas", "ref")
    if jax.default_backend() != "tpu":
        assert resolve_backend("auto") == "ref"
        with pytest.raises(ValueError, match="requires a TPU"):
            resolve_backend("pallas")
    assert resolve_backend(None) == resolve_backend("auto")
    assert resolve_backend(INTERP) == INTERP
    assert resolve_backend("ref") == "ref"
    with pytest.raises(ValueError, match="unknown kernel backend"):
        resolve_backend("triton")


# ---------------------------------------------------------------------------
# ensemble_kl VJP: cotangents for client_logits, student_logits and w


@pytest.mark.parametrize("k,b,v,temp", [(3, 13, 700, 4.0), (2, 5, 96, 1.0), (4, 8, 512, 2.0)])
def test_ensemble_kl_grad_parity(k, b, v, temp):
    """Kernel-vs-ref gradients for all three differentiable inputs, with a
    random per-sample cotangent (covers padded batch + vocab tails)."""
    assert_loss_grad_parity("ensemble_kl", loss_case(0, k, b, v), temperature=temp)


def test_ensemble_kl_grad_numerical():
    """check_grads against finite differences through the interpret kernel."""
    cl = jax.random.normal(jax.random.key(0), (2, 4, 32))
    st = jax.random.normal(jax.random.key(1), (4, 32))
    w = jnp.asarray([0.6, 0.4])
    f = lambda cl, st, w: jnp.sum(ensemble_kl(cl, st, w, temperature=2.0, backend=INTERP))
    check_kernel_grads(f, (cl, st, w))


def test_ensemble_kl_server_params_cotangent():
    """server_params-shaped grads: differentiate a linear student head
    through the kernel loss; the tree must match the ref path."""
    k, b, d, v = 3, 8, 16, 128
    x = jax.random.normal(jax.random.key(0), (b, d))
    cl = jax.random.normal(jax.random.key(1), (k, b, v)) * 2
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (k,)))
    sp = {
        "w": jax.random.normal(jax.random.key(3), (d, v)) * 0.1,
        "b": jnp.zeros((v,)),
    }
    apply = lambda p: x @ p["w"] + p["b"]

    def loss(p, backend):
        if backend == "ref":
            return jnp.mean(ensemble_kl_ref(cl, apply(p), w, 4.0))
        return jnp.mean(ensemble_kl(cl, apply(p), w, temperature=4.0, backend=backend))

    got = jax.grad(loss)(sp, INTERP)
    want = jax.grad(loss)(sp, "ref")
    assert_tree_close(got, want)


def test_ensemble_kl_w_cotangent_feeds_ee_sign_step():
    """The w gradient through the kernel must agree in sign with the ref
    path (the EE update of Eq. 12 consumes only the sign)."""
    k, b, v = 4, 16, 256
    cl = jax.random.normal(jax.random.key(0), (k, b, v)) * 3
    st = jax.random.normal(jax.random.key(1), (b, v))
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (k,)))
    g_ker = jax.grad(lambda w: jnp.mean(ensemble_kl(cl, st, w, backend=INTERP)))(w)
    g_ref = jax.grad(lambda w: jnp.mean(ensemble_kl_ref(cl, st, w)))(w)
    np.testing.assert_allclose(g_ker, g_ref, rtol=TOL, atol=TOL)
    np.testing.assert_array_equal(np.sign(g_ker), np.sign(g_ref))


# ---------------------------------------------------------------------------
# ghm_ce VJP: cotangents for client_logits and w, int labels get float0


@pytest.mark.parametrize("k,b,v", [(3, 13, 700), (2, 5, 96)])
@pytest.mark.parametrize("weighted", [True, False])
@pytest.mark.parametrize("stop_difficulty_grad", [True, False])
def test_ghm_ce_grad_parity(k, b, v, weighted, stop_difficulty_grad):
    assert_loss_grad_parity(
        "ghm_ce", loss_case(0, k, b, v),
        weighted=weighted, stop_difficulty_grad=stop_difficulty_grad,
    )


def test_ghm_ce_grad_numerical():
    cl = jax.random.normal(jax.random.key(0), (2, 4, 32))
    lbl = jax.random.randint(jax.random.key(1), (4,), 0, 32)
    w = jnp.asarray([0.3, 0.7])
    f = lambda cl, w: jnp.sum(ghm_ce(cl, lbl, w, backend=INTERP))
    check_kernel_grads(f, (cl, w))


# ---------------------------------------------------------------------------
# flash_attention VJP: dq/dk/dv from the saved lse, via the public op


ATTN_CASES = [
    # (b, sq, sk, h, kh, hd, causal, window, softcap) — GQA, SWA, softcap,
    # cross-attention lengths, and non-tile-aligned tails (13, 9, 20)
    (2, 16, 16, 4, 2, 32, True, 0, 0.0),
    (1, 13, 13, 3, 3, 16, True, 5, 30.0),
    (2, 9, 24, 4, 1, 8, False, 0, 0.0),
    (1, 20, 20, 2, 2, 64, True, 0, 50.0),
]


def _attn_args(b, sq, sk, h, kh, hd, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    return (
        jax.random.normal(ks[0], (b, sq, h, hd)),
        jax.random.normal(ks[1], (b, sk, kh, hd)),
        jax.random.normal(ks[2], (b, sk, kh, hd)),
    )


@pytest.mark.parametrize("b,sq,sk,h,kh,hd,causal,window,softcap", ATTN_CASES)
def test_flash_attention_grad_parity(b, sq, sk, h, kh, hd, causal, window, softcap):
    """dq/dk/dv through the fused Pallas backward vs plain autodiff of the
    jnp reference, with a fixed non-trivial output cotangent."""
    q, k, v = _attn_args(b, sq, sk, h, kh, hd)
    ct = jax.random.normal(jax.random.key(9), q.shape)

    def f(backend, q, k, v):
        if backend == "ref":
            out = flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
        else:
            out = flash_attention(
                q, k, v, causal=causal, window=window, softcap=softcap,
                backend=backend, block_q=8, block_kv=8,
            )
        return jnp.vdot(out, ct)

    got = jax.grad(partial(f, INTERP), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(partial(f, "ref"), argnums=(0, 1, 2))(q, k, v)
    assert_tree_close(got, want)


def test_flash_attention_grad_numerical():
    q, k, v = _attn_args(1, 8, 8, 2, 1, 16, seed=3)
    f = lambda q, k, v: jnp.sum(flash_attention(q, k, v, causal=True, backend=INTERP))
    check_kernel_grads(f, (q, k, v))


def test_flash_attention_padded_tail_grads_are_exact_zero_free():
    """Non-multiple-of-block shapes: the sliced grads must carry no leakage
    from the padded rows/columns (parity at the padded geometry)."""
    q, k, v = _attn_args(1, 5, 11, 2, 2, 8, seed=7)

    def f(backend, q, k, v):
        if backend == "ref":
            return jnp.sum(flash_attention_ref(q, k, v, causal=True) ** 2)
        return jnp.sum(
            flash_attention(q, k, v, causal=True, backend=backend, block_q=8, block_kv=8) ** 2
        )

    got = jax.grad(partial(f, INTERP), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(partial(f, "ref"), argnums=(0, 1, 2))(q, k, v)
    assert_tree_close(got, want)
    assert all(g.shape == x.shape for g, x in zip(got, (q, k, v)))


# ---------------------------------------------------------------------------
# fused epoch engine: "ref" and "pallas-interpret" backends produce the same
# server params on the same PRNG stream — the contract that retired the
# legacy driver, for all five methods on the grouped client bank


@pytest.fixture(scope="module")
def tiny_market_kernelpath():
    return build_tiny_market()


@pytest.mark.parametrize("method", METHODS)
def test_fused_epoch_backend_parity(method, tiny_market_kernelpath):
    """End-to-end grad steps: every generator/EE/KD optimizer step of one
    fused epoch runs its backward through the backend under test; ref and
    interpret runs must land on the same server params and weights."""
    assert_method_backend_parity(method, tiny_market_kernelpath)

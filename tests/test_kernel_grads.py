"""Differentiable kernel path: custom_vjp grad parity vs the jnp oracles,
backend dispatch rules, and fused-epoch equivalence of the "ref" and
"pallas-interpret" loss paths.

The VJP contract (repro/kernels/*/ops.py): the Pallas forward returns its
online softmax statistics as residuals and the backward produces cotangents
for ``client_logits``, ``student_logits`` and ``w`` — the student cotangent
drives server distillation (Eq. 4), the w cotangent the EE step (Eq. 12).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.kernels import (
    ensemble_kl,
    ensemble_kl_ref,
    ghm_ce,
    ghm_ce_ref,
    resolve_backend,
)

pytestmark = pytest.mark.tier1

INTERP = "pallas-interpret"
TOL = 1e-4


def _assert_tree_close(a, b, tol=TOL):
    for u, v in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# dispatch rules


def test_dispatch_auto_never_interprets_off_tpu():
    assert resolve_backend("auto") in ("pallas", "ref")
    if jax.default_backend() != "tpu":
        assert resolve_backend("auto") == "ref"
        with pytest.raises(ValueError, match="requires a TPU"):
            resolve_backend("pallas")
    assert resolve_backend(None) == resolve_backend("auto")
    assert resolve_backend(INTERP) == INTERP
    assert resolve_backend("ref") == "ref"
    with pytest.raises(ValueError, match="unknown kernel backend"):
        resolve_backend("triton")


# ---------------------------------------------------------------------------
# ensemble_kl VJP: cotangents for client_logits, student_logits and w


@pytest.mark.parametrize("k,b,v,temp", [(3, 13, 700, 4.0), (2, 5, 96, 1.0), (4, 8, 512, 2.0)])
def test_ensemble_kl_grad_parity(k, b, v, temp):
    """Kernel-vs-ref gradients for all three differentiable inputs, with a
    random per-sample cotangent (covers padded batch + vocab tails)."""
    cl = jax.random.normal(jax.random.key(0), (k, b, v)) * 2
    st = jax.random.normal(jax.random.key(1), (b, v)) * 2
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (k,)))
    ct = jax.random.normal(jax.random.key(3), (b,))

    def f_ker(cl, st, w):
        return jnp.vdot(ensemble_kl(cl, st, w, temperature=temp, backend=INTERP), ct)

    def f_ref(cl, st, w):
        return jnp.vdot(ensemble_kl_ref(cl, st, w, temp), ct)

    got = jax.grad(f_ker, argnums=(0, 1, 2))(cl, st, w)
    want = jax.grad(f_ref, argnums=(0, 1, 2))(cl, st, w)
    _assert_tree_close(got, want)


def test_ensemble_kl_grad_numerical():
    """check_grads against finite differences through the interpret kernel."""
    cl = jax.random.normal(jax.random.key(0), (2, 4, 32))
    st = jax.random.normal(jax.random.key(1), (4, 32))
    w = jnp.asarray([0.6, 0.4])
    f = lambda cl, st, w: jnp.sum(ensemble_kl(cl, st, w, temperature=2.0, backend=INTERP))
    check_grads(f, (cl, st, w), order=1, modes=("rev",), atol=1e-2, rtol=1e-2)


def test_ensemble_kl_server_params_cotangent():
    """server_params-shaped grads: differentiate a linear student head
    through the kernel loss; the tree must match the ref path."""
    k, b, d, v = 3, 8, 16, 128
    x = jax.random.normal(jax.random.key(0), (b, d))
    cl = jax.random.normal(jax.random.key(1), (k, b, v)) * 2
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (k,)))
    sp = {
        "w": jax.random.normal(jax.random.key(3), (d, v)) * 0.1,
        "b": jnp.zeros((v,)),
    }
    apply = lambda p: x @ p["w"] + p["b"]

    def loss(p, backend):
        if backend == "ref":
            return jnp.mean(ensemble_kl_ref(cl, apply(p), w, 4.0))
        return jnp.mean(ensemble_kl(cl, apply(p), w, temperature=4.0, backend=backend))

    got = jax.grad(loss)(sp, INTERP)
    want = jax.grad(loss)(sp, "ref")
    _assert_tree_close(got, want)


def test_ensemble_kl_w_cotangent_feeds_ee_sign_step():
    """The w gradient through the kernel must agree in sign with the ref
    path (the EE update of Eq. 12 consumes only the sign)."""
    k, b, v = 4, 16, 256
    cl = jax.random.normal(jax.random.key(0), (k, b, v)) * 3
    st = jax.random.normal(jax.random.key(1), (b, v))
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (k,)))
    g_ker = jax.grad(lambda w: jnp.mean(ensemble_kl(cl, st, w, backend=INTERP)))(w)
    g_ref = jax.grad(lambda w: jnp.mean(ensemble_kl_ref(cl, st, w)))(w)
    np.testing.assert_allclose(g_ker, g_ref, rtol=TOL, atol=TOL)
    np.testing.assert_array_equal(np.sign(g_ker), np.sign(g_ref))


# ---------------------------------------------------------------------------
# ghm_ce VJP: cotangents for client_logits and w, int labels get float0


@pytest.mark.parametrize("k,b,v", [(3, 13, 700), (2, 5, 96)])
@pytest.mark.parametrize("weighted", [True, False])
@pytest.mark.parametrize("stop_difficulty_grad", [True, False])
def test_ghm_ce_grad_parity(k, b, v, weighted, stop_difficulty_grad):
    cl = jax.random.normal(jax.random.key(0), (k, b, v)) * 2
    lbl = jax.random.randint(jax.random.key(1), (b,), 0, v)
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (k,)))
    ct = jax.random.normal(jax.random.key(3), (b,))

    def f_ker(cl, w):
        out = ghm_ce(cl, lbl, w, weighted=weighted, backend=INTERP,
                     stop_difficulty_grad=stop_difficulty_grad)
        return jnp.vdot(out, ct)

    def f_ref(cl, w):
        return jnp.vdot(ghm_ce_ref(cl, lbl, w, weighted, stop_difficulty_grad), ct)

    got = jax.grad(f_ker, argnums=(0, 1))(cl, w)
    want = jax.grad(f_ref, argnums=(0, 1))(cl, w)
    _assert_tree_close(got, want)


def test_ghm_ce_grad_numerical():
    cl = jax.random.normal(jax.random.key(0), (2, 4, 32))
    lbl = jax.random.randint(jax.random.key(1), (4,), 0, 32)
    w = jnp.asarray([0.3, 0.7])
    f = lambda cl, w: jnp.sum(ghm_ce(cl, lbl, w, backend=INTERP))
    check_grads(f, (cl, w), order=1, modes=("rev",), atol=1e-2, rtol=1e-2)


# ---------------------------------------------------------------------------
# fused epoch engine: "ref" and "pallas-interpret" backends produce the same
# server params on the same PRNG stream


@pytest.mark.parametrize("method", ["coboosting", "dense"])
def test_fused_epoch_backend_parity(method, tiny_market_kernelpath):
    from repro.core import default_image_setup, run_coboosting, run_generator_baseline
    from repro.models.cnn import cnn_apply, init_cnn

    cfg, applies, params, classes, shape = tiny_market_kernelpath
    results = {}
    for backend in ("ref", INTERP):
        import dataclasses

        c = dataclasses.replace(cfg, kernel_backend=backend)
        server_apply = partial(cnn_apply, "mlp")
        sp = init_cnn(jax.random.key(99), "mlp", classes, shape)
        gen_apply, gp = default_image_setup(jax.random.key(5), c, classes, shape)
        if method == "coboosting":
            st = run_coboosting(
                applies, params, server_apply, sp, gen_apply, gp, c, classes,
                jax.random.key(0),
            )
        else:
            st = run_generator_baseline(
                method, applies, params, server_apply, sp, gen_apply, gp, c, classes,
                jax.random.key(0),
            )
        results[backend] = st

    _assert_tree_close(results["ref"].server_params, results[INTERP].server_params, tol=1e-4)
    np.testing.assert_allclose(
        np.asarray(results["ref"].weights), np.asarray(results[INTERP].weights), atol=1e-5
    )


@pytest.fixture(scope="module")
def tiny_market_kernelpath():
    from repro.config.train import OFLConfig
    from repro.data import make_synth_images
    from repro.fed import build_market

    classes, shape = 4, (8, 8, 3)
    cfg = OFLConfig(
        num_clients=2, local_epochs=1, local_batch_size=16,
        epochs=3, gen_iters=2, batch_size=8, latent_dim=8, buffer_batches=2,
    )
    x, y = make_synth_images(0, classes, 20, shape)
    applies, params, _, _ = build_market(0, x, y, cfg, classes, archs=["mlp", "mlp"])
    return cfg, applies, params, classes, shape

"""Telemetry subsystem tests (repro.obs).

* registry: counter/gauge/histogram semantics, labels, snapshot/Prometheus
  export, the disabled no-op fast path;
* StatsView: the dict-shaped adapter the serving components mutate through —
  old ``stats["x"] += 1`` call sites must keep working verbatim, unknown
  keys must raise (drift guard);
* tracer: nested spans nest correctly, Chrome trace JSON round-trips
  ``json.loads`` with per-thread monotonic ``ts``, a disabled tracer records
  nothing and costs one shared no-op context;
* the serving hot path: enabling trace/metrics must not add host syncs to a
  decode chunk (the O(1)-syncs-per-chunk contract), and what a smoke run
  increments must match the namespace ``repro.obs.names`` declares;
* per-request timelines: ``Completion.first_token``/TTFT and the
  ``latency_summary`` percentiles;
* the artifact validator CI runs, and the REPRO_LOG_LEVEL logging knob.
"""
from __future__ import annotations

import json
import logging

import jax
import numpy as np
import pytest

from repro import obs
from repro.config import ModelConfig
from repro.models import init_lm
from repro.obs import (
    KV_GAUGES,
    REQUIRED_SERVE_KEYS,
    SERVE_ENGINE_METRICS,
    MetricsRegistry,
    SpanTracer,
    serve_namespace,
)
from repro.obs.tracer import _NULL_SPAN
from repro.obs.validate import validate_metrics, validate_trace
from repro.serve import (
    ContinuousScheduler,
    EngineConfig,
    ManualClock,
    Request,
    ServeEngine,
)
from repro.serve.metrics import latency_summary
from repro.serve.scheduler import Completion


def _mk(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, scan_layers=False,
        remat=False, dtype="float32", param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture
def global_obs_off():
    """Tests that flip the process-global telemetry restore the default."""
    yield
    obs.configure(metrics=False, trace=False)
    obs.tracer().clear()
    obs.registry().reset()


# ---------------------------------------------------------------------------
# metrics registry


def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.inc("a.b", 2)
    reg.inc("a.b", 3)
    reg.inc("a.b", 1, replica=1)
    reg.set_gauge("g.x", 7.5, replica=0)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.observe("h.t", v)
    assert reg.value("a.b") == 5
    assert reg.value("a.b", replica=1) == 1
    assert reg.total("a.b") == 6
    assert reg.names("a.") == ["a.b"]
    recs = {(r["name"], tuple(sorted(r["labels"].items()))): r for r in reg.snapshot()}
    assert recs[("a.b", ())]["value"] == 5
    assert recs[("g.x", (("replica", "0"),))]["type"] == "gauge"
    h = recs[("h.t", ())]
    assert h["count"] == 4 and h["sum"] == 10.0 and h["min"] == 1.0 and h["max"] == 4.0
    assert h["p50"] == 2.5


def test_registry_label_order_is_canonical():
    reg = MetricsRegistry()
    reg.inc("x", 1, a=1, b=2)
    reg.inc("x", 1, b=2, a=1)  # same series regardless of kwarg order
    assert reg.value("x", a=1, b=2) == 2


def test_registry_disabled_is_noop():
    reg = MetricsRegistry(enabled=False)
    reg.inc("a.b")
    reg.set_gauge("g", 1)
    reg.observe("h", 1.0)
    assert reg.snapshot() == []
    assert reg.value("a.b") == 0


def test_registry_histogram_ring_bound():
    reg = MetricsRegistry(hist_capacity=8)
    for i in range(50):
        reg.observe("h", float(i))
    (rec,) = reg.snapshot()
    assert rec["count"] == 8
    assert rec["min"] == 42.0  # oldest samples dropped


def test_prometheus_export_shape():
    reg = MetricsRegistry()
    reg.inc("serve.admit.requests", 3, replica=0)
    reg.observe("serve.request.ttft_s", 0.5)
    text = reg.to_prometheus()
    assert '# TYPE serve_admit_requests counter' in text
    assert 'serve_admit_requests{replica="0"} 3' in text
    assert '# TYPE serve_request_ttft_s summary' in text
    assert 'serve_request_ttft_s{quantile="0.5"} 0.5' in text
    assert 'serve_request_ttft_s_count 1' in text


def test_registry_dump_writes_jsonl_and_prom(tmp_path):
    reg = MetricsRegistry()
    reg.inc("a.b", 4)
    out = tmp_path / "m.jsonl"
    reg.dump(str(out))
    [rec] = [json.loads(l) for l in out.read_text().splitlines()]
    assert rec == {"name": "a.b", "type": "counter", "labels": {}, "value": 4}
    assert (tmp_path / "m.prom").read_text().startswith("# TYPE a_b counter")


# ---------------------------------------------------------------------------
# StatsView


def test_stats_view_preserves_dict_semantics():
    reg = MetricsRegistry()
    st = reg.view({"hits": "c.hits", "misses": "c.misses"}, replica=3)
    st["hits"] += 1
    st["hits"] += 1
    st["misses"] = 5  # plain assignment (the spec_decode mirror idiom)
    assert st["hits"] == 2 and isinstance(st["hits"], int)
    assert dict(st) == {"hits": 2, "misses": 5}
    assert len(st) == 2 and "hits" in st and "other" not in st
    # mutations landed in the namespaced labelled series
    assert reg.value("c.hits", replica=3) == 2
    # reset-by-iteration, as ServeEngine.reset()/FleetRouter.run() do
    for k in list(st):
        st[k] = 0
    assert dict(st) == {"hits": 0, "misses": 0}


def test_stats_view_rejects_unknown_keys():
    st = MetricsRegistry().view({"hits": "c.hits"})
    with pytest.raises(KeyError):
        st["typo"] += 1
    with pytest.raises(KeyError):
        st["typo"] = 1
    with pytest.raises(TypeError):
        del st["hits"]


# ---------------------------------------------------------------------------
# span tracer


def test_nested_spans_nest_correctly():
    tr = SpanTracer()
    tr.enabled = True
    with tr.span("outer", kind="parent"):
        with tr.span("inner"):
            pass
        with tr.span("inner2"):
            pass
    evs = {e["name"]: e for e in tr.events()}
    assert set(evs) == {"outer", "inner", "inner2"}
    outer, inner = evs["outer"], evs["inner"]
    assert inner["args"]["parent"] == "outer"
    assert evs["inner2"]["args"]["parent"] == "outer"
    assert "parent" not in outer.get("args", {})
    # containment: the child interval sits inside the parent's
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    # export order: parent precedes the children it contains
    assert [e["name"] for e in tr.events()][0] == "outer"


def test_trace_json_roundtrip_and_monotonic_ts(tmp_path):
    tr = SpanTracer()
    tr.enabled = True
    for i in range(5):
        with tr.span("step", i=i):
            with tr.span("sub"):
                pass
    doc = json.loads(json.dumps(tr.to_chrome_trace()))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 10
    last = {}
    for ev in evs:
        assert ev["ph"] == "X" and "dur" in ev
        tid = ev["tid"]
        assert ev["ts"] >= last.get(tid, float("-inf"))
        last[tid] = ev["ts"]
    # and the dumped file passes the CI validator
    p = tmp_path / "trace.json"
    tr.dump(str(p))
    assert len(validate_trace(str(p))) == 10


def test_disabled_tracer_records_nothing():
    tr = SpanTracer()
    s1 = tr.span("a")
    s2 = tr.span("b", x=1)
    assert s1 is s2 is _NULL_SPAN  # shared no-op: no per-call allocation
    with s1:
        tr.instant("marker")
    assert len(tr) == 0 and tr.events() == []


def test_tracer_ring_is_bounded():
    tr = SpanTracer(capacity=16)
    tr.enabled = True
    for i in range(100):
        with tr.span("s", i=i):
            pass
    assert len(tr) == 16


# ---------------------------------------------------------------------------
# serving hot path: sync contract + namespace drift guard


def _run_tiny_engine(registry=None, gen=6):
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    eng = ServeEngine(
        cfg, params,
        EngineConfig(max_slots=2, max_seq=32, max_new=8, decode_chunk=4,
                     kv_layout="paged", page_size=8),
        registry=registry,
    )
    prompts = [np.arange(6, dtype=np.int32) % cfg.vocab_size,
               (np.arange(7, dtype=np.int32) * 3) % cfg.vocab_size]
    sched = ContinuousScheduler(eng, clock=ManualClock(tick=0.01))
    comps = sched.run(
        [Request(rid=i, tokens=p, max_new_tokens=gen, arrival=0.0)
         for i, p in enumerate(prompts)]
    )
    return eng, sched, comps


def test_telemetry_adds_no_host_syncs(global_obs_off):
    """The O(1)-syncs-per-chunk contract holds with telemetry disabled AND
    enabled: spans bracket host actions, they never force a device sync."""
    eng_off, _, _ = _run_tiny_engine()
    assert len(obs.tracer()) == 0  # disabled tracer saw the whole run
    assert eng_off.stats["host_syncs"] == eng_off.stats["decode_chunks"]

    obs.configure(metrics=True, trace=True)
    eng_on, _, _ = _run_tiny_engine()
    assert eng_on.stats["host_syncs"] == eng_on.stats["decode_chunks"]
    assert eng_on.stats["host_syncs"] == eng_off.stats["host_syncs"]
    assert len(obs.tracer()) > 0  # enabled tracer actually recorded spans
    names = {e["name"] for e in obs.tracer().events()}
    assert {"serve.decode_chunk", "serve.prefill", "serve.admit"} <= names


def test_serve_namespace_matches_smoke_run():
    """Drift guard: everything a paged smoke run touches is declared in
    repro.obs.names, and the run increments at least the required floor."""
    reg = MetricsRegistry()
    eng, sched, comps = _run_tiny_engine(registry=reg)
    assert len(comps) == 2
    eng.publish_gauges()
    touched = set(reg.names("serve."))
    assert touched <= serve_namespace()
    assert set(REQUIRED_SERVE_KEYS) <= touched
    # pool gauges always publish; reclaimable_pages needs --prefix-cache
    assert {KV_GAUGES[k] for k in ("free_pages", "pages_in_use", "capacity_pages")} <= touched
    # the engine's stats keys are exactly the declared schema
    assert set(eng.stats) == set(SERVE_ENGINE_METRICS)
    # fleet aggregation: engine counters land with the replica label
    assert reg.value("serve.decode.chunks", replica=0) == eng.stats["decode_chunks"]


# ---------------------------------------------------------------------------
# per-request timelines (TTFT)


def test_completion_ttft_and_summary():
    c = Completion(rid=0, prompt_len=4, tokens=np.zeros(3, np.int32),
                   arrival=1.0, admitted=1.5, finished=3.0, first_token=1.75)
    assert c.ttft == pytest.approx(0.75)
    assert c.queue_wait == pytest.approx(0.5)
    legacy = Completion(rid=1, prompt_len=4, tokens=np.zeros(3, np.int32),
                        arrival=0.0, admitted=0.5, finished=2.0)
    assert legacy.ttft is None
    s = latency_summary([c, legacy], wall_s=2.0)
    assert s["ttft_p50_s"] == pytest.approx(0.75)  # None-TTFT rows excluded
    assert s["ttft_p95_s"] == pytest.approx(0.75)
    assert s["tokens"] == 6.0


def test_scheduler_stamps_first_token():
    reg = MetricsRegistry()
    _, sched, comps = _run_tiny_engine(registry=reg)
    for c in comps:
        assert c.first_token is not None
        # admitted is stamped before the prefill dispatch, first_token after
        assert c.arrival <= c.admitted < c.first_token <= c.finished
        assert c.ttft >= c.queue_wait
    for name in ("serve.request.latency_s", "serve.request.queue_wait_s",
                 "serve.request.ttft_s"):
        (rec,) = [r for r in reg.snapshot() if r["name"] == name]
        assert rec["count"] == len(comps)


# ---------------------------------------------------------------------------
# validator


def test_validate_trace_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [
        {"name": "a", "ph": "X", "ts": 10.0, "dur": 1.0, "tid": 1},
        {"name": "b", "ph": "X", "ts": 5.0, "dur": 1.0, "tid": 1},
    ]}))
    with pytest.raises(ValueError, match="non-monotonic"):
        validate_trace(str(bad))
    bad.write_text(json.dumps({"traceEvents": [{"name": "a", "ph": "X", "ts": 1.0}]}))
    with pytest.raises(ValueError, match="missing dur"):
        validate_trace(str(bad))
    bad.write_text("not json")
    with pytest.raises(json.JSONDecodeError):
        validate_trace(str(bad))


def test_validate_metrics_requires_serve_keys(tmp_path):
    reg = MetricsRegistry()
    reg.inc("serve.admit.requests")
    p = tmp_path / "m.jsonl"
    reg.dump(str(p))
    with pytest.raises(ValueError, match="missing required keys"):
        validate_metrics(str(p))
    for name in REQUIRED_SERVE_KEYS:
        reg.inc(name)
    reg.dump(str(p))
    assert len(validate_metrics(str(p))) == len(REQUIRED_SERVE_KEYS)


# ---------------------------------------------------------------------------
# logging knob


def test_log_level_env_and_set_level(monkeypatch):
    from repro.utils.logging import _level_from_env, set_level

    monkeypatch.setenv("REPRO_LOG_LEVEL", "debug")
    assert _level_from_env() == logging.DEBUG
    monkeypatch.setenv("REPRO_LOG_LEVEL", "WARNING")
    assert _level_from_env() == logging.WARNING
    monkeypatch.setenv("REPRO_LOG_LEVEL", "15")
    assert _level_from_env() == 15
    monkeypatch.setenv("REPRO_LOG_LEVEL", "bogus")
    assert _level_from_env() == logging.INFO
    monkeypatch.delenv("REPRO_LOG_LEVEL")
    assert _level_from_env() == logging.INFO

    root = logging.getLogger("repro")
    before = root.level
    try:
        set_level("error")
        assert root.level == logging.ERROR
        set_level(logging.DEBUG)
        assert root.level == logging.DEBUG
        with pytest.raises(ValueError):
            set_level("nope")
    finally:
        root.setLevel(before)

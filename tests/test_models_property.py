"""Property tests on the model substrate's invariants:
 * prefill-then-decode must equal one full forward (KV cache coherence),
   for every decode-capable family;
 * the chunked mamba scan must equal the step-by-step recurrence;
 * the chunk-checkpointed xLSTM scan must be chunk-size invariant;
 * flash attention (jnp twin) must equal naive attention for random shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.config import ModelConfig
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models import init_lm, init_lm_state, lm_decode, lm_forward, lm_prefill
from repro.models.attention import flash_attn_jax
from repro.models.mamba import init_mamba, init_mamba_state, mamba_decode, mamba_forward
from repro.models.xlstm import init_mlstm, mlstm_forward

SETTINGS = dict(max_examples=10, deadline=None)


def _mk(family, **kw):
    base = dict(
        name="t", family=family, num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, scan_layers=False,
        remat=False, dtype="float32", param_dtype="float32", ssm_chunk=8,
        # ample capacity: decode (1 token) never drops, so full-forward
        # consistency requires the grouped path not to drop either
        moe_capacity_factor=8.0,
    )
    base.update(kw)
    return ModelConfig(**base)


FAMILIES = [
    _mk("dense"),
    _mk("moe", num_experts=4, experts_per_token=2),
    _mk("ssm", ssm_kind="mamba", d_ff=0, num_kv_heads=4),
    _mk("ssm", ssm_kind="xlstm", d_ff=0, slstm_every=2, xlstm_heads=2, num_kv_heads=4),
    _mk("hybrid", ssm_kind="mamba", num_layers=4, attn_every=4, moe_every=2,
        num_experts=4, experts_per_token=2),
    _mk("dense", sliding_window=8),
]


@pytest.mark.parametrize("cfg", FAMILIES, ids=lambda c: f"{c.family}-{c.ssm_kind or c.sliding_window or 'plain'}")
def test_decode_matches_full_forward(cfg):
    """Greedy per-token decode with the cache must reproduce the logits of a
    single full-sequence forward at every position."""
    b, s = 2, 12
    params = init_lm(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    full_logits, _ = lm_forward(params, cfg, {"tokens": tokens})

    state = init_lm_state(cfg, b, s)
    # prefill on the first s0 tokens, then decode the rest one by one
    s0 = 5
    pre_logits, state = lm_prefill(params, cfg, {"tokens": tokens[:, :s0]}, state)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]), np.asarray(full_logits[:, s0 - 1]), rtol=2e-4, atol=2e-4
    )
    for t in range(s0, s):
        logits_t, state = lm_decode(params, cfg, tokens[:, t : t + 1], state, jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]),
            np.asarray(full_logits[:, t]),
            rtol=2e-4,
            atol=2e-4,
            err_msg=f"{cfg.family}/{cfg.ssm_kind} mismatch at position {t}",
        )


def test_mamba_chunked_equals_stepwise():
    cfg = _mk("ssm", ssm_kind="mamba", d_ff=0, num_kv_heads=4, ssm_chunk=4)
    p = init_mamba(jax.random.key(0), cfg, jnp.float32)
    b, s = 2, 16
    x = jax.random.normal(jax.random.key(1), (b, s, cfg.d_model)) * 0.5
    y_full = mamba_forward(p, x, cfg)
    state = init_mamba_state(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        y_t, state = mamba_decode(p, x[:, t : t + 1], cfg, state)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step), rtol=2e-4, atol=2e-4)


@given(st.sampled_from([2, 4, 8, 16]))
@settings(**SETTINGS)
def test_mlstm_chunk_invariance(chunk):
    cfg = _mk("ssm", ssm_kind="xlstm", d_ff=0, xlstm_heads=2, num_kv_heads=4, ssm_chunk=chunk)
    p = init_mlstm(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.5
    ref_cfg = cfg.replace(ssm_chunk=16)  # single chunk = plain scan
    np.testing.assert_allclose(
        np.asarray(mlstm_forward(p, x, cfg)),
        np.asarray(mlstm_forward(p, x, ref_cfg)),
        rtol=2e-4,
        atol=2e-4,
    )


@given(
    st.integers(1, 3),  # batch
    st.integers(3, 48),  # seq
    st.sampled_from([(4, 2), (4, 4), (2, 1)]),  # (H, KH)
    st.sampled_from([16, 32]),  # hd
    st.booleans(),  # causal
)
@settings(**SETTINGS)
def test_flash_attn_jax_property(b, s, heads, hd, causal):
    h, kh = heads
    q = jax.random.normal(jax.random.key(0), (b, s, h, hd))
    k = jax.random.normal(jax.random.key(1), (b, s, kh, hd))
    v = jax.random.normal(jax.random.key(2), (b, s, kh, hd))
    got = flash_attn_jax(q, k, v, causal=causal, q_block=8, kv_block=8)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_scan_layers_matches_unrolled():
    """scan-over-layers (+remat) is a pure compilation strategy — numerics
    must match the unrolled python loop exactly."""
    cfg_scan = _mk("dense", num_layers=4, scan_layers=True, remat=True)
    cfg_loop = cfg_scan.replace(scan_layers=False, remat=False)
    params = init_lm(cfg_scan, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 10), 0, cfg_scan.vocab_size)
    a, _ = lm_forward(params, cfg_scan, {"tokens": tokens})
    b, _ = lm_forward(params, cfg_loop, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)

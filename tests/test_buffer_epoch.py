"""Device ring buffer + fused scan epoch driver: parity with the legacy
python-loop semantics.

Pins the tentpole contracts:
  * ring wraparound/eviction reproduces the legacy ``append`` + ``pop(0)``
    list window at ``buffer_batches`` capacity;
  * ``distill_schedule`` replays the legacy host-side batch permutation,
    mapped to physical slots (valid-first so the PRNG split chain aligns);
  * a fused epoch produces numerically equivalent server params to the
    legacy per-batch loop on a tiny CNN config (same PRNG stream), for
    Co-Boosting and the DENSE baseline;
  * one epoch is O(1) jitted dispatches, independent of buffer size.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.train import OFLConfig
from repro.core import (
    buffer_append,
    buffer_as_lists,
    buffer_init,
    default_image_setup,
    distill_schedule,
    logical_to_slot,
    run_coboosting,
    run_generator_baseline,
)
from repro.data import make_synth_images
from repro.fed import build_market
from repro.models.cnn import cnn_apply, init_cnn

pytestmark = pytest.mark.tier1

CLASSES = 4
SHAPE = (8, 8, 3)


# ---------------------------------------------------------------------------
# ring buffer semantics


@pytest.mark.parametrize("capacity", [1, 3, 4])
def test_ring_matches_list_window(capacity):
    """Appends through several wraparounds equal the legacy list's
    append+pop(0) window, oldest-first."""
    b, obs = 2, (3,)
    buf = buffer_init(capacity, (b, *obs))
    ref_x, ref_y = [], []
    for t in range(3 * capacity + 1):
        x = jnp.full((b, *obs), float(t))
        y = jnp.full((b,), t, jnp.int32)
        buf = buffer_append(buf, x, y)
        ref_x.append(x)
        ref_y.append(y)
        if len(ref_x) > capacity:
            ref_x.pop(0)
            ref_y.pop(0)
        got_x, got_y = buffer_as_lists(buf)
        assert len(got_x) == len(ref_x) == min(t + 1, capacity)
        for gx, rx, gy, ry in zip(got_x, ref_x, got_y, ref_y):
            np.testing.assert_array_equal(np.asarray(gx), np.asarray(rx))
            np.testing.assert_array_equal(np.asarray(gy), np.asarray(ry))


def test_buffer_append_traceable_under_jit():
    buf = buffer_init(3, (2, 4))
    step = jax.jit(buffer_append)
    for t in range(5):
        buf = step(buf, jnp.full((2, 4), float(t)), jnp.full((2,), t, jnp.int32))
    assert int(buf.size) == 3 and int(buf.ptr) == 5 % 3
    xs, ys = buffer_as_lists(buf)
    assert [int(y[0]) for y in ys] == [2, 3, 4]


def test_distill_schedule_replays_legacy_permutation():
    """slot_order[:size] must visit the same batches, in the same order, as
    the legacy ``RandomState(epoch).permutation(len(buffer))`` over the
    oldest-first list."""
    capacity = 4
    for epoch in range(11):
        size = min(epoch + 1, capacity)
        ptr = (epoch + 1) % capacity
        order, n_valid = distill_schedule(epoch, capacity)
        assert int(n_valid) == size
        perm = np.random.RandomState(epoch).permutation(size)
        want = [int(logical_to_slot(i, ptr, size, capacity)) for i in perm]
        assert list(np.asarray(order)[:size]) == want


# ---------------------------------------------------------------------------
# fused epoch ≡ legacy loop


@pytest.fixture(scope="module")
def tiny_market():
    cfg = OFLConfig(
        num_clients=2, local_epochs=2, local_batch_size=16,
        epochs=7, gen_iters=3, batch_size=8, latent_dim=8, buffer_batches=3,
    )
    x, y = make_synth_images(0, CLASSES, 30, SHAPE)
    applies, params, _, _ = build_market(0, x, y, cfg, CLASSES, archs=["mlp", "mlp"])
    return cfg, applies, params


def _max_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(u.astype(jnp.float32) - v.astype(jnp.float32))))
        for u, v in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _run(driver, cfg, applies, params, method="coboosting"):
    server_apply = partial(cnn_apply, "mlp")
    server_params = init_cnn(jax.random.key(99), "mlp", CLASSES, SHAPE)
    gen_apply, gen_params = default_image_setup(jax.random.key(5), cfg, CLASSES, SHAPE)
    if method == "coboosting":
        return run_coboosting(
            applies, params, server_apply, server_params, gen_apply, gen_params,
            cfg, CLASSES, jax.random.key(0), driver=driver,
        )
    return run_generator_baseline(
        method, applies, params, server_apply, server_params, gen_apply, gen_params,
        cfg, CLASSES, jax.random.key(0), driver=driver,
    )


def test_fused_epoch_matches_legacy_coboosting(tiny_market):
    cfg, applies, params = tiny_market
    fused = _run("fused", cfg, applies, params)
    legacy = _run("legacy", cfg, applies, params)
    # same PRNG stream + same batch order => same trajectory, up to float
    # reassociation between the fused scan and the per-batch dispatches
    assert _max_diff(fused.server_params, legacy.server_params) < 1e-4
    np.testing.assert_allclose(
        np.asarray(fused.weights), np.asarray(legacy.weights), atol=1e-5
    )
    assert len(fused.buffer_x) == len(legacy.buffer_x) == cfg.buffer_batches
    for fx, lx in zip(fused.buffer_x, legacy.buffer_x):
        np.testing.assert_allclose(np.asarray(fx), np.asarray(lx), atol=1e-4)


def test_fused_epoch_matches_legacy_dense(tiny_market):
    cfg, applies, params = tiny_market
    fused = _run("fused", cfg, applies, params, method="dense")
    legacy = _run("legacy", cfg, applies, params, method="dense")
    assert _max_diff(fused.server_params, legacy.server_params) < 1e-4


def test_legacy_driver_is_deprecated(tiny_market):
    """driver="legacy" still runs (the parity pins above depend on it) but
    is a deprecated alias scheduled for removal — the grad-parity oracle is
    now backend="ref" under the fused driver (tests/grad_harness.py)."""
    cfg, applies, params = tiny_market
    cfg = dataclasses.replace(cfg, epochs=1)
    with pytest.warns(DeprecationWarning, match="driver='legacy' is deprecated"):
        _run("legacy", cfg, applies, params)


def test_fused_driver_dispatches_constant_in_buffer_size(tiny_market):
    """O(1) dispatches per epoch: the epoch_step call count equals the epoch
    count whatever the buffer capacity (the legacy loop's per-epoch dispatch
    count grows with the buffer instead)."""
    cfg, applies, params = tiny_market
    counts = {}
    for cap in (2, 5):
        scaled = dataclasses.replace(cfg, buffer_batches=cap, epochs=6)
        counts[cap] = _run("fused", scaled, applies, params).dispatch_count
    assert counts[2] == counts[5] == 6

"""Serving-path tests.

 * flash-attention kernel vs the jnp twin INSIDE full model forwards
   (attn_prefill / attn_train), incl. causal + sliding window + ragged tail,
   and gradient parity through the Pallas custom-vjp;
 * attention backend dispatch rules (auto never interprets off-TPU);
 * continuous engine vs fused static batch: exact greedy token parity for
   identical prompts (incl. slot reuse and bucketed ragged prompts), in
   BOTH KV layouts — the static arm is always dense, so the paged run pins
   paged==dense token-for-token across full/SWA/softcap attention;
 * the fused static path vs the legacy per-token decode loop;
 * O(1) host syncs per decode chunk (the zero-per-token-sync contract);
 * scheduler invariants under randomized admission: every request drains,
   no slot leaks, slots never double-booked — and in the paged layout, no
   page leaks and free-list conservation on every transition;
 * launch.serve fail-fast argument audit (incl. the paged-KV knobs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.kernels.dispatch import resolve_backend
from repro.models import init_lm, init_lm_state, lm_decode, lm_prefill
from repro.models.transformer import lm_loss
from repro.serve import (
    ContinuousScheduler,
    EngineConfig,
    ManualClock,
    Request,
    ServeEngine,
    hot_prefix_stream,
    staggered_stream,
    static_generate,
)


def _mk(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, scan_layers=False,
        remat=False, dtype="float32", param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# flash-attention kernel inside the model forward


@pytest.mark.parametrize(
    "kw,seq",
    [
        ({}, 32),  # causal, block-aligned
        ({}, 33),  # ragged tail (not a block multiple)
        ({"sliding_window": 8}, 29),  # causal + window + ragged
        ({"attn_logit_softcap": 20.0}, 16),  # softcap chain
    ],
    ids=["causal", "ragged", "window", "softcap"],
)
def test_prefill_kernel_matches_ref_in_model(kw, seq):
    """kernel_backend='ref' vs 'pallas-interpret' produce matching prefill
    logits through the full attn_prefill forward (acceptance criterion)."""
    cfg = _mk(**kw)
    params = init_lm(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, seq), 0, cfg.vocab_size)
    l_ref, st_ref = lm_prefill(
        params, cfg.replace(attn_backend="ref"), {"tokens": tokens},
        init_lm_state(cfg, 2, seq + 4),
    )
    l_pal, st_pal = lm_prefill(
        params, cfg.replace(attn_backend="pallas-interpret"), {"tokens": tokens},
        init_lm_state(cfg, 2, seq + 4),
    )
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_pal), rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(st_ref), jax.tree_util.tree_leaves(st_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_train_grads_kernel_matches_ref():
    """The Pallas forward's custom-vjp (jnp recompute backward fed the
    kernel's lse) matches plain autodiff of the jnp twin in attn_train."""
    cfg = _mk(sliding_window=8)
    params = init_lm(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    g_ref = jax.grad(lambda p: lm_loss(p, cfg.replace(attn_backend="ref"), batch)[0])(params)
    g_pal = jax.grad(
        lambda p: lm_loss(p, cfg.replace(attn_backend="pallas-interpret"), batch)[0]
    )(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_attn_backend_dispatch_rules():
    if jax.default_backend() == "tpu":
        assert resolve_backend("auto") == "pallas"
    else:
        # auto never interprets off-TPU; explicit pallas is an error, not a fallback
        assert resolve_backend("auto") == "ref"
        with pytest.raises(ValueError, match="requires a TPU"):
            resolve_backend("pallas")
    assert resolve_backend("pallas-interpret") == "pallas-interpret"


# ---------------------------------------------------------------------------
# continuous engine vs static batch


@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.parametrize(
    "kw", [{}, {"sliding_window": 8}, {"attn_logit_softcap": 20.0}],
    ids=["full", "swa", "softcap"],
)
def test_engine_matches_static_tokens(kw, layout):
    """Identical prompts through the slot engine and the fused static batch
    yield identical greedy tokens — including ragged bucketed prompts,
    prompts longer than the SWA window, and slot reuse (requests > slots).
    The static arm always decodes the dense cache, so the paged runs are the
    paged==dense acceptance pin across the decode feature matrix."""
    cfg = _mk(**kw)
    params = init_lm(cfg, jax.random.key(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32) for n in (7, 12, 12, 5, 13)]
    gen = 8
    refs = [
        np.asarray(static_generate(params, cfg, {"tokens": jnp.asarray(p[None])}, gen, max_seq=48))[0]
        for p in prompts
    ]
    eng = ServeEngine(
        cfg, params,
        EngineConfig(
            max_slots=2, max_seq=48, max_new=gen, decode_chunk=3, prefill_bucket=8,
            kv_layout=layout, page_size=16,
        ),
    )
    comps = ContinuousScheduler(eng, clock=ManualClock()).run(
        [Request(rid=i, tokens=p, max_new_tokens=gen) for i, p in enumerate(prompts)]
    )
    assert [c.rid for c in comps] == list(range(len(prompts)))
    for c, ref in zip(comps, refs):
        np.testing.assert_array_equal(c.tokens, ref)
    if layout == "paged":
        assert eng.pool.pages_in_use == 0 and eng.pool.free_pages == eng.pool.n_pages


def test_engine_paged_matches_dense_ragged_budgets():
    """Paged vs dense engines on the SAME ragged-budget staggered stream:
    token-for-token identical completions, with slot reuse and decode-time
    page appends in play (a tight pool forces the append path)."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    reqs = staggered_stream(
        cfg.vocab_size, 7, seed=4, prompt_range=(4, 14), budget_range=(2, 9),
    )
    outs = {}
    for layout, pool_pages in (("dense", 0), ("paged", 8)):
        eng = ServeEngine(
            cfg, params,
            EngineConfig(
                max_slots=2, max_seq=48, max_new=8, decode_chunk=3, prefill_bucket=8,
                kv_layout=layout, page_size=8, pool_pages=pool_pages,
            ),
        )
        comps = ContinuousScheduler(eng, clock=ManualClock(tick=0.2)).run(reqs)
        outs[layout] = {c.rid: c.tokens for c in comps}
        if layout == "paged":
            assert eng.stats["page_appends"] > 0  # the append path actually ran
    assert outs["dense"].keys() == outs["paged"].keys()
    for rid in outs["dense"]:
        np.testing.assert_array_equal(outs["dense"][rid], outs["paged"][rid])


def test_scheduler_defers_admission_on_tight_pool():
    """A pool too small for a full burst DEFERS the excess (requests stay
    queued until a drain returns pages) instead of crashing the run — and
    the deferred stream still matches the dense engine token-for-token. A
    request that outbills even the empty pool raises up front."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    rng = np.random.RandomState(6)
    prompts = [rng.randint(0, cfg.vocab_size, size=20).astype(np.int32) for _ in range(4)]
    reqs = [Request(rid=i, tokens=p, max_new_tokens=4) for i, p in enumerate(prompts)]
    outs = {}
    for layout, pool_pages in (("dense", 0), ("paged", 4)):
        # paged: a 20-token prompt buckets to 32 tokens = ALL 4 pages, so
        # only ONE request fits at a time even though 2 slots are free —
        # the pool, not the slot count, is the binding constraint here
        eng = ServeEngine(
            cfg, params,
            EngineConfig(
                max_slots=2, max_seq=32, max_new=4, decode_chunk=4,
                prefill_bucket=16, kv_layout=layout, page_size=8,
                pool_pages=pool_pages,
            ),
        )
        comps = ContinuousScheduler(eng, clock=ManualClock()).run(reqs)
        outs[layout] = {c.rid: c.tokens for c in comps}
        assert sorted(outs[layout]) == [0, 1, 2, 3]  # every request drained
        if layout == "paged":
            assert eng.stats["admitted"] == 4
            assert eng.pool.pages_in_use == 0
    for rid in outs["dense"]:
        np.testing.assert_array_equal(outs["dense"][rid], outs["paged"][rid])

    # budget-driven deferral: prefills alone fit together, but admission
    # bills LIFETIMES (prompt+budget), so the requests serve one at a time
    # and decode growth can never exhaust the pool mid-run
    eng = ServeEngine(
        cfg, params,
        EngineConfig(
            max_slots=2, max_seq=32, max_new=16, decode_chunk=4,
            prefill_bucket=8, page_size=8, pool_pages=4,
        ),
    )
    comps = ContinuousScheduler(eng, clock=ManualClock()).run(
        [Request(rid=i, tokens=np.arange(8, dtype=np.int32), max_new_tokens=16)
         for i in range(2)]
    )
    assert sorted(c.rid for c in comps) == [0, 1]
    assert all(len(c.tokens) == 16 for c in comps)
    assert eng.pool.pages_in_use == 0

    # impossible request: bills more than the WHOLE pool — fail fast, not hang
    eng = ServeEngine(
        cfg, params,
        EngineConfig(
            max_slots=2, max_seq=32, max_new=4, decode_chunk=4,
            prefill_bucket=8, page_size=8, pool_pages=2,
        ),
    )
    big = Request(rid=0, tokens=rng.randint(0, 64, size=26).astype(np.int32), max_new_tokens=4)
    with pytest.raises(RuntimeError, match="never be admitted"):
        ContinuousScheduler(eng, clock=ManualClock()).run([big])


def test_engine_paged_idle_slots_cannot_clobber():
    """Regression: an evicted slot keeps rewriting its frozen position as it
    rides along in the batched decode. Its stale page-table row must be
    re-aimed at the scratch page BEFORE its old pages are reissued — here a
    short request drains early (its slot stays idle; no refill queued) while
    the survivors' decode-time appends pop exactly the returned pages. With
    a stale row, the idle slot's writes land INSIDE a live slot's new page.
    Greedy argmax can mask that (degenerate random-init streams), so this
    pins the cache CONTENTS: every live logical position of the survivors'
    paged caches must equal the dense engine's rows bit-for-bit."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, size=4).astype(np.int32) for _ in range(3)]
    budgets = [2, 14, 14]  # index 0 drains after the first chunk, slot never refilled

    def drive(layout):
        eng = ServeEngine(
            cfg, params,
            EngineConfig(
                max_slots=3, max_seq=32, max_new=16, decode_chunk=2, prefill_bucket=4,
                kv_layout=layout, page_size=4, pool_pages=12,
            ),
        )
        slots = eng.admit_many(list(zip(prompts, budgets)))
        freed_pages = None
        for _ in range(20):
            eng.decode_chunk()
            active, n_out = eng.sync()
            if not active[slots[0]] and freed_pages is None:
                if eng.pool is not None:
                    freed_pages = set(eng.pool.owned(slots[0]))
                eng.fetch(slots[0], int(n_out[slots[0]]))  # early drain; no refill
            if not active.any():
                break
        assert not active.any()
        return eng, slots, freed_pages

    eng_d, slots_d, _ = drive("dense")
    eng_p, slots_p, freed = drive("paged")
    assert slots_d == slots_p
    # the hazard really occurred: survivors' appends reissued the freed pages
    survivors_pages = {
        p for s in (slots_p[1], slots_p[2]) for p in eng_p.pool.owned(s)
    }
    assert freed and freed <= survivors_pages

    # tight allclose, not bitwise: the two layouts are different XLA programs
    # (~1e-6 reassociation noise); a clobbered position differs by O(1)
    dense_kv = jax.device_get(eng_d._state.kv)
    paged_kv = jax.device_get(eng_p._state.kv)
    table = np.asarray(eng_p._state.page_table)
    ps = 4
    for idx in (1, 2):  # the survivors
        slot = slots_p[idx]
        live = 4 + budgets[idx]  # prompt + generated positions
        for key in dense_kv:  # p0, p1, ... per-group stacks
            for dn, pn in (("k", "k_pages"), ("v", "v_pages")):
                dense_rows = dense_kv[key][dn][:, slot]  # (G, cl, KH, hd)
                pages = paged_kv[key][pn]  # (G, P, ps, KH, hd)
                for j in range(live):
                    got = pages[:, table[slot, j // ps], j % ps]
                    np.testing.assert_allclose(
                        got, dense_rows[:, j], rtol=1e-4, atol=1e-4,
                        err_msg=f"{key}/{pn} slot {slot} logical pos {j} clobbered",
                    )
    assert eng_p.stats["table_resets"] >= 1  # the idle slot was re-aimed


def test_static_generate_matches_legacy_loop():
    """The fused scan accumulates the same greedy tokens the retired
    per-token host-sync loop produced."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 10), 0, cfg.vocab_size)
    gen = 6
    got = np.asarray(static_generate(params, cfg, {"tokens": tokens}, gen))

    state = init_lm_state(cfg, 2, 10 + gen)
    logits, state = lm_prefill(params, cfg, {"tokens": tokens}, state)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    for i in range(gen - 1):
        logits, state = lm_decode(params, cfg, tok, state, jnp.asarray(10 + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    np.testing.assert_array_equal(got, np.concatenate(out, axis=1))


def test_decode_host_syncs_O1_per_chunk():
    """The zero-per-token-sync contract: host syncs equal decode chunks
    (each a single dispatch of up to ``decode_chunk`` steps), so generating
    more tokens with the same chunking adds syncs sublinearly in tokens —
    the legacy loop did one sync per token."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    counts = {}
    for gen in (4, 16):
        eng = ServeEngine(
            cfg, params,
            EngineConfig(max_slots=1, max_seq=48, max_new=16, decode_chunk=8),
        )
        ContinuousScheduler(eng, clock=ManualClock()).run(
            [Request(rid=0, tokens=prompt, max_new_tokens=gen)]
        )
        assert eng.stats["host_syncs"] == eng.stats["decode_chunks"]
        # gen-1 decode steps in ceil((gen-1)/chunk) dispatches
        assert eng.stats["decode_chunks"] == -(-(gen - 1) // 8)
        counts[gen] = eng.stats["host_syncs"]
    assert counts[16] < 16  # not one sync per token
    assert counts[16] == 2 and counts[4] == 1


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_scheduler_randomized_invariants(layout):
    """Randomized admission: every request drains exactly once with its full
    budget, slots are never double-booked, and no slot leaks. In the paged
    layout the auditing wrapper additionally asserts pool hygiene on every
    transition: free + owned partitions the pool, no page double-booked."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    eng = ServeEngine(
        cfg, params,
        EngineConfig(
            max_slots=3, max_seq=48, max_new=10, decode_chunk=4, prefill_bucket=8,
            kv_layout=layout, page_size=8,
        ),
    )
    requests = staggered_stream(
        cfg.vocab_size, 11, seed=7, prompt_range=(3, 20), budget_range=(1, 11),
        arrival_span=5.0,
    )
    # ticking clock: time passes per scheduler iteration, so arrivals land
    # MID-decode and freed slots are refilled while others keep decoding

    class AuditEngine:
        """Delegating wrapper asserting slot AND page hygiene on every
        transition."""

        def __init__(self, inner):
            self._e = inner
            self.in_use = set()

        def __getattr__(self, name):
            return getattr(self._e, name)

        def _check_pool(self):
            pool = self._e.pool
            if pool is None:
                return
            owned = [p for s in range(self._e.ecfg.max_slots) for p in pool.owned(s)]
            assert len(owned) == len(set(owned)), "page double-booked"
            assert pool.free_pages + len(owned) == pool.n_pages, "free-list leak"
            # only resident slots hold pages
            assert all(not pool.owned(s) for s in self._e.free_slots)

        def admit_many(self, requests):
            slots = self._e.admit_many(requests)
            assert len(set(slots)) == len(slots), f"burst reused a slot: {slots}"
            for slot in slots:
                assert slot not in self.in_use, f"slot {slot} double-booked"
                self.in_use.add(slot)
            self._check_pool()
            return slots

        def decode_chunk(self):
            self._e.decode_chunk()  # may append pages mid-decode
            self._check_pool()

        def fetch(self, slot, n_out):
            assert slot in self.in_use
            self.in_use.discard(slot)
            toks = self._e.fetch(slot, n_out)
            self._check_pool()
            return toks

    audit = AuditEngine(eng)
    comps = ContinuousScheduler(audit, clock=ManualClock(tick=0.3)).run(requests)
    assert sorted(c.rid for c in comps) == sorted(r.rid for r in requests)
    by_rid = {c.rid: c for c in comps}
    for r in requests:
        c = by_rid[r.rid]
        assert len(c.tokens) == r.max_new_tokens  # no EOS configured: full budget
        assert c.admitted >= r.arrival and c.finished >= c.admitted
    assert not audit.in_use
    assert sorted(eng.free_slots) == [0, 1, 2]  # no slot leak
    assert not bool(np.asarray(eng._state.active).any())
    assert eng.stats["evicted"] == eng.stats["admitted"] == len(requests)
    if eng.pool is not None:
        assert eng.pool.pages_in_use == 0 and eng.pool.free_pages == eng.pool.n_pages


# ---------------------------------------------------------------------------
# radix prefix cache: splice == cold parity


_PCFG = dict(
    max_slots=2, max_seq=48, max_new=8, decode_chunk=3, prefill_bucket=8,
    page_size=8,
)


def _run_pair(cfg, params, reqs, ecfg_a, ecfg_b, drafter_b=None, tick=0.2):
    """The same stream through two engines; returns (comps_a, comps_b,
    engine_a, engine_b) with completions keyed by rid."""
    outs, engs = [], []
    for ecfg, drafter in ((ecfg_a, None), (ecfg_b, drafter_b)):
        eng = ServeEngine(cfg, params, ecfg, drafter=drafter)
        comps = ContinuousScheduler(eng, clock=ManualClock(tick=tick)).run(reqs)
        outs.append({c.rid: c.tokens for c in comps})
        engs.append(eng)
    assert outs[0].keys() == outs[1].keys()
    return outs[0], outs[1], engs[0], engs[1]


def test_prefix_splice_matches_cold_tokens():
    """A hot-prefix admission via page splice produces bitwise-identical
    greedy tokens to a cold full prefill — the tentpole parity pin. The
    stream re-serves one prompt twice and a one-page-longer extension of it,
    so the r>0 tail path runs with both 2- and 2.5-page matches, and the
    spliced engine demonstrably prefills fewer tokens for the same output."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    rng = np.random.RandomState(11)
    p0 = rng.randint(0, cfg.vocab_size, size=20).astype(np.int32)  # 2 full pages + 4
    p_ext = np.concatenate([p0, rng.randint(0, cfg.vocab_size, size=8).astype(np.int32)])
    p_cold = rng.randint(0, cfg.vocab_size, size=13).astype(np.int32)
    prompts = [p0, p0, p_ext, p_cold, p0]
    # arrivals serialize the admissions: an insertion must land before the
    # re-serve of the same prefix probes for it
    reqs = [
        Request(rid=i, tokens=p, max_new_tokens=4 + (i % 3), arrival=2.0 * i)
        for i, p in enumerate(prompts)
    ]
    cold, hot, ce, he = _run_pair(
        cfg, params, reqs,
        EngineConfig(**_PCFG), EngineConfig(prefix_cache=True, **_PCFG),
    )
    for rid in cold:
        np.testing.assert_array_equal(cold[rid], hot[rid])
    assert he.stats["spliced_admissions"] >= 3  # rids 1, 2, 4
    assert he.stats["spliced_pages"] >= 6
    # the whole point: spliced admissions skip the covered head's prefill
    assert he.stats["prefill_tokens"] < ce.stats["prefill_tokens"]
    assert he.stats["pages_allocated"] < ce.stats["pages_allocated"]


def test_prefix_fully_covered_prompt_replays_via_cow():
    """A prompt the cache covers COMPLETELY (r == 0) still needs one
    replayed token for its first logits — and that token's KV write lands in
    a SHARED page, so admission must copy-on-write it. Greedy tokens stay
    bitwise identical to the cold serve."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    rng = np.random.RandomState(12)
    p = rng.randint(0, cfg.vocab_size, size=16).astype(np.int32)  # exactly 2 pages
    reqs = [
        Request(rid=i, tokens=p, max_new_tokens=5, arrival=2.0 * i) for i in range(3)
    ]
    cold, hot, ce, he = _run_pair(
        cfg, params, reqs,
        EngineConfig(**_PCFG), EngineConfig(prefix_cache=True, **_PCFG),
    )
    for rid in cold:
        np.testing.assert_array_equal(cold[rid], hot[rid])
    assert he.stats["spliced_admissions"] == 2  # rids 1 and 2
    assert he.stats["cow_copies"] >= 2  # the replayed last-page write, each time
    assert he.stats["prefill_tokens"] == ce.stats["prefill_tokens"] - 2 * 15
    # pinned pages stay resident after every owner drained
    assert he.prefix.cached_pages > 0 and he.pool.pages_in_use > 0


def test_prefix_cache_eviction_and_slot_reuse_parity():
    """Hot-prefix traffic through a POOL-TIGHT engine: admissions must evict
    cached (refcount-1) pages to make room, slots recycle across requests,
    and decode growth CoWs shared pages mid-stream — greedy tokens still
    match the cache-less engine bitwise, and the pool drains clean."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    prompts, budgets = hot_prefix_stream(
        cfg.vocab_size, 10, 16, 8, seed=2, budget_min=3, shared_fraction=0.6,
    )
    reqs = [
        Request(rid=i, tokens=p, max_new_tokens=b, arrival=0.7 * i)
        for i, (p, b) in enumerate(zip(prompts, budgets))
    ]
    base = dict(_PCFG, pool_pages=8)  # 2 slots x (2-page prompt + growth): tight
    cold, hot, ce, he = _run_pair(
        cfg, params, reqs,
        EngineConfig(**base), EngineConfig(prefix_cache=True, **base),
    )
    for rid in cold:
        np.testing.assert_array_equal(cold[rid], hot[rid])
    assert he.stats["spliced_admissions"] > 0
    assert sorted(he.free_slots) == [0, 1]  # slots recycled, none leaked
    # every non-pinned page accounted for: residual use is all cache pins
    assert he.pool.pages_in_use == he.prefix.cached_pages


# ---------------------------------------------------------------------------
# speculative decoding: spec == non-spec parity


@pytest.mark.parametrize("matched", [True, False], ids=["matched", "mismatched"])
def test_spec_decode_matches_plain_tokens(matched):
    """The speculative engine produces bitwise-identical greedy tokens to
    the non-speculative engine — whatever the drafter proposes. A MATCHED
    drafter (the target itself) must certify most drafts (the acceptance
    ceiling); a mismatched random drafter degrades acceptance toward zero
    but NEVER token output."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    if matched:
        drafter = (cfg, params)
    else:
        dcfg = _mk(num_layers=1, d_model=16, num_heads=2, num_kv_heads=1, d_ff=32)
        drafter = (dcfg, init_lm(dcfg, jax.random.key(9)))
    reqs = staggered_stream(
        cfg.vocab_size, 7, seed=4, prompt_range=(4, 14), budget_range=(2, 9),
    )
    plain, spec, pe, se = _run_pair(
        cfg, params, reqs,
        EngineConfig(**_PCFG), EngineConfig(spec_k=3, **_PCFG),
        drafter_b=drafter,
    )
    for rid in plain:
        np.testing.assert_array_equal(plain[rid], spec[rid])
    assert se.stats["spec_steps"] > 0 and se.stats["draft_proposed"] > 0
    acc = se.stats["draft_accepted"] / se.stats["draft_proposed"]
    if matched:
        assert acc > 0.5, f"matched drafter should certify most drafts, got {acc:.2f}"
        # certifying k+1 tokens per verify means FEWER dispatches; a
        # rejected-everything drafter instead degrades to ~1 token/verify
        assert se.stats["decode_chunks"] <= pe.stats["decode_chunks"]


def test_spec_with_prefix_cache_combined_parity():
    """Both accelerations at once on hot-prefix traffic: spliced admissions
    feed the drafter full prompts, decode CoWs shared pages under the
    speculative chunk's wider write horizon — and tokens still match the
    plain engine bitwise."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    prompts, budgets = hot_prefix_stream(
        cfg.vocab_size, 8, 16, 6, seed=5, budget_min=2, shared_fraction=0.5,
    )
    reqs = [
        Request(rid=i, tokens=p, max_new_tokens=b, arrival=1.0 * i)
        for i, (p, b) in enumerate(zip(prompts, budgets))
    ]
    plain, boosted, pe, be = _run_pair(
        cfg, params, reqs,
        EngineConfig(**_PCFG),
        EngineConfig(prefix_cache=True, spec_k=3, **_PCFG),
        drafter_b=(cfg, params),
    )
    for rid in plain:
        np.testing.assert_array_equal(plain[rid], boosted[rid])
    assert be.stats["spliced_admissions"] > 0 and be.stats["spec_steps"] > 0


def test_prefix_spec_config_fail_fast():
    """Every inconsistent prefix-cache / spec-decode knob dies at
    construction with a clear message — config-level where the config
    suffices, engine-level where the arch or drafter is needed."""
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(kv_layout="dense", prefix_cache=True)
    with pytest.raises(ValueError, match="temperature"):
        EngineConfig(spec_k=2, temperature=0.7)
    with pytest.raises(ValueError, match=">= 0"):
        EngineConfig(spec_k=-1)
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    spec_cfg = EngineConfig(spec_k=2, **_PCFG)
    with pytest.raises(ValueError, match="no drafter"):
        ServeEngine(cfg, params, spec_cfg)
    with pytest.raises(ValueError, match="spec_k == 0"):
        ServeEngine(cfg, params, EngineConfig(**_PCFG), drafter=(cfg, params))
    # drafter gates: rollback needs an attention-only FULL cache + one vocab
    swa = _mk(sliding_window=8)
    with pytest.raises(ValueError, match="ring"):
        ServeEngine(cfg, params, spec_cfg, drafter=(swa, params))
    ssm = _mk(family="ssm", ssm_kind="mamba", d_ff=0, num_kv_heads=4)
    with pytest.raises(ValueError, match="attention-only"):
        ServeEngine(cfg, params, spec_cfg, drafter=(ssm, params))
    other_vocab = _mk(vocab_size=32)
    with pytest.raises(ValueError, match="vocab"):
        ServeEngine(cfg, params, spec_cfg, drafter=(other_vocab, params))


# ---------------------------------------------------------------------------
# launch.serve argument audit


def test_serve_args_fail_fast():
    from repro.launch.serve import build_parser, validate_args
    from repro.config import get_arch

    parser = build_parser()
    dec = get_arch("smollm-135m")
    enc = get_arch("hubert-xlarge")

    with pytest.raises(SystemExit, match="encoder-only"):
        validate_args(parser.parse_args([]), enc)
    with pytest.raises(SystemExit, match="vlm"):
        validate_args(parser.parse_args([]), get_arch("phi-3-vision-4.2b"))
    validate_args(parser.parse_args(["--engine", "static"]), get_arch("phi-3-vision-4.2b"))
    # multipod serving is now the fleet-router path for the continuous
    # engine; only the fused STATIC program stays single-pod
    validate_args(parser.parse_args(["--mesh", "multipod"]), dec)
    with pytest.raises(SystemExit, match="multipod"):
        validate_args(parser.parse_args(["--mesh", "multipod", "--engine", "static"]), dec)
    with pytest.raises(SystemExit, match="paged"):
        # the prefill->decode handoff moves sealed pages: dense has none
        validate_args(parser.parse_args(["--disagg", "--kv-layout", "dense"]), dec)
    with pytest.raises(SystemExit, match="replicas"):
        validate_args(parser.parse_args(["--replicas", "0"]), dec)
    with pytest.raises(SystemExit, match="continuous"):
        validate_args(parser.parse_args(["--replicas", "2", "--engine", "static"]), dec)
    with pytest.raises(SystemExit, match="max-slots"):
        validate_args(parser.parse_args(["--max-slots", "0"]), dec)
    with pytest.raises(SystemExit, match="gen"):
        validate_args(parser.parse_args(["--gen", "0"]), dec)
    with pytest.raises(SystemExit, match="power of two"):
        validate_args(parser.parse_args(["--page-size", "12"]), dec)
    with pytest.raises(SystemExit, match="pool-pages"):
        validate_args(parser.parse_args(["--pool-pages", "-1"]), dec)
    with pytest.raises(SystemExit, match="at least one page"):
        # EngineConfig's own floor, surfaced by the dry construction
        validate_args(parser.parse_args(["--pool-pages", "2", "--max-slots", "4"]), dec)
    with pytest.raises(SystemExit, match="exhaust the pool"):
        # passes the per-slot floor (4 >= 4) but not the bucket_min bill —
        # the dry EngineConfig construction catches it pre-device
        validate_args(parser.parse_args(["--pool-pages", "4", "--max-slots", "4"]), dec)
    # prefix-cache / spec-decode audits
    with pytest.raises(SystemExit, match="prefix-cache"):
        validate_args(parser.parse_args(["--prefix-cache", "--kv-layout", "dense"]), dec)
    with pytest.raises(SystemExit, match="hot-fraction"):
        validate_args(parser.parse_args(["--hot-fraction", "1.5"]), dec)
    with pytest.raises(SystemExit, match="spec-k"):
        validate_args(parser.parse_args(["--spec-decode", "--spec-k", "0"]), dec)
    with pytest.raises(SystemExit, match="temperature"):
        validate_args(parser.parse_args(["--spec-decode", "--temperature", "0.7"]), dec)
    with pytest.raises(SystemExit, match="paged"):
        validate_args(parser.parse_args(["--spec-decode", "--kv-layout", "dense"]), dec)
    with pytest.raises(SystemExit, match="attention-only"):
        # recurrent mixers cannot roll back past a rejected draft
        validate_args(parser.parse_args(["--spec-decode", "--drafter", "xlstm-125m"]), dec)
    with pytest.raises(SystemExit, match="sliding window"):
        # an SWA ring aliases stale rejected-draft writes after rollback
        validate_args(parser.parse_args(["--spec-decode", "--drafter", "mixtral-8x7b"]), dec)
    with pytest.raises(SystemExit, match="vocab"):
        validate_args(parser.parse_args(["--spec-decode", "--drafter", "granite-3-2b"]), dec)
    # both features on their defaults pass the dry construction
    validate_args(parser.parse_args(["--prefix-cache", "--spec-decode"]), dec)
    # dense layout ignores page knobs; static engine ignores them entirely
    validate_args(parser.parse_args(["--kv-layout", "dense", "--page-size", "12"]), dec)
    validate_args(parser.parse_args(["--engine", "static", "--page-size", "12"]), dec)
    validate_args(parser.parse_args([]), dec)  # defaults pass

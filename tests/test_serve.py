"""Serving-path tests.

 * flash-attention kernel vs the jnp twin INSIDE full model forwards
   (attn_prefill / attn_train), incl. causal + sliding window + ragged tail,
   and gradient parity through the Pallas custom-vjp;
 * attention backend dispatch rules (auto never interprets off-TPU);
 * continuous engine vs fused static batch: exact greedy token parity for
   identical prompts (incl. slot reuse and bucketed ragged prompts);
 * the fused static path vs the legacy per-token decode loop;
 * O(1) host syncs per decode chunk (the zero-per-token-sync contract);
 * scheduler invariants under randomized admission: every request drains,
   no slot leaks, slots never double-booked;
 * launch.serve fail-fast argument audit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.kernels.dispatch import resolve_backend
from repro.models import init_lm, init_lm_state, lm_decode, lm_prefill
from repro.models.transformer import lm_loss
from repro.serve import (
    ContinuousScheduler,
    EngineConfig,
    ManualClock,
    Request,
    ServeEngine,
    static_generate,
)


def _mk(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, scan_layers=False,
        remat=False, dtype="float32", param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# flash-attention kernel inside the model forward


@pytest.mark.parametrize(
    "kw,seq",
    [
        ({}, 32),  # causal, block-aligned
        ({}, 33),  # ragged tail (not a block multiple)
        ({"sliding_window": 8}, 29),  # causal + window + ragged
        ({"attn_logit_softcap": 20.0}, 16),  # softcap chain
    ],
    ids=["causal", "ragged", "window", "softcap"],
)
def test_prefill_kernel_matches_ref_in_model(kw, seq):
    """kernel_backend='ref' vs 'pallas-interpret' produce matching prefill
    logits through the full attn_prefill forward (acceptance criterion)."""
    cfg = _mk(**kw)
    params = init_lm(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, seq), 0, cfg.vocab_size)
    l_ref, st_ref = lm_prefill(
        params, cfg.replace(attn_backend="ref"), {"tokens": tokens},
        init_lm_state(cfg, 2, seq + 4),
    )
    l_pal, st_pal = lm_prefill(
        params, cfg.replace(attn_backend="pallas-interpret"), {"tokens": tokens},
        init_lm_state(cfg, 2, seq + 4),
    )
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_pal), rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(st_ref), jax.tree_util.tree_leaves(st_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_train_grads_kernel_matches_ref():
    """The Pallas forward's custom-vjp (jnp recompute backward fed the
    kernel's lse) matches plain autodiff of the jnp twin in attn_train."""
    cfg = _mk(sliding_window=8)
    params = init_lm(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    g_ref = jax.grad(lambda p: lm_loss(p, cfg.replace(attn_backend="ref"), batch)[0])(params)
    g_pal = jax.grad(
        lambda p: lm_loss(p, cfg.replace(attn_backend="pallas-interpret"), batch)[0]
    )(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_attn_backend_dispatch_rules():
    if jax.default_backend() == "tpu":
        assert resolve_backend("auto") == "pallas"
    else:
        # auto never interprets off-TPU; explicit pallas is an error, not a fallback
        assert resolve_backend("auto") == "ref"
        with pytest.raises(ValueError, match="requires a TPU"):
            resolve_backend("pallas")
    assert resolve_backend("pallas-interpret") == "pallas-interpret"


# ---------------------------------------------------------------------------
# continuous engine vs static batch


@pytest.mark.parametrize("kw", [{}, {"sliding_window": 8}], ids=["dense", "swa"])
def test_engine_matches_static_tokens(kw):
    """Identical prompts through the slot engine and the fused static batch
    yield identical greedy tokens — including ragged bucketed prompts,
    prompts longer than the SWA window, and slot reuse (requests > slots)."""
    cfg = _mk(**kw)
    params = init_lm(cfg, jax.random.key(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32) for n in (7, 12, 12, 5, 13)]
    gen = 8
    refs = [
        np.asarray(static_generate(params, cfg, {"tokens": jnp.asarray(p[None])}, gen, max_seq=48))[0]
        for p in prompts
    ]
    eng = ServeEngine(
        cfg, params,
        EngineConfig(max_slots=2, max_seq=48, max_new=gen, decode_chunk=3, prefill_bucket=8),
    )
    comps = ContinuousScheduler(eng, clock=ManualClock()).run(
        [Request(rid=i, tokens=p, max_new_tokens=gen) for i, p in enumerate(prompts)]
    )
    assert [c.rid for c in comps] == list(range(len(prompts)))
    for c, ref in zip(comps, refs):
        np.testing.assert_array_equal(c.tokens, ref)


def test_static_generate_matches_legacy_loop():
    """The fused scan accumulates the same greedy tokens the retired
    per-token host-sync loop produced."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 10), 0, cfg.vocab_size)
    gen = 6
    got = np.asarray(static_generate(params, cfg, {"tokens": tokens}, gen))

    state = init_lm_state(cfg, 2, 10 + gen)
    logits, state = lm_prefill(params, cfg, {"tokens": tokens}, state)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    for i in range(gen - 1):
        logits, state = lm_decode(params, cfg, tok, state, jnp.asarray(10 + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    np.testing.assert_array_equal(got, np.concatenate(out, axis=1))


def test_decode_host_syncs_O1_per_chunk():
    """The zero-per-token-sync contract: host syncs equal decode chunks
    (each a single dispatch of up to ``decode_chunk`` steps), so generating
    more tokens with the same chunking adds syncs sublinearly in tokens —
    the legacy loop did one sync per token."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    counts = {}
    for gen in (4, 16):
        eng = ServeEngine(
            cfg, params,
            EngineConfig(max_slots=1, max_seq=40, max_new=16, decode_chunk=8),
        )
        ContinuousScheduler(eng, clock=ManualClock()).run(
            [Request(rid=0, tokens=prompt, max_new_tokens=gen)]
        )
        assert eng.stats["host_syncs"] == eng.stats["decode_chunks"]
        # gen-1 decode steps in ceil((gen-1)/chunk) dispatches
        assert eng.stats["decode_chunks"] == -(-(gen - 1) // 8)
        counts[gen] = eng.stats["host_syncs"]
    assert counts[16] < 16  # not one sync per token
    assert counts[16] == 2 and counts[4] == 1


def test_scheduler_randomized_invariants():
    """Randomized admission: every request drains exactly once with its full
    budget, slots are never double-booked, and no slot leaks."""
    cfg = _mk()
    params = init_lm(cfg, jax.random.key(0))
    eng = ServeEngine(
        cfg, params,
        EngineConfig(max_slots=3, max_seq=48, max_new=10, decode_chunk=4, prefill_bucket=8),
    )
    rng = np.random.RandomState(7)
    requests = [
        Request(
            rid=i,
            tokens=rng.randint(0, cfg.vocab_size, size=rng.randint(3, 20)).astype(np.int32),
            max_new_tokens=int(rng.randint(1, 11)),
            arrival=float(rng.uniform(0.0, 5.0)),
        )
        for i in range(11)
    ]
    # ticking clock: time passes per scheduler iteration, so arrivals land
    # MID-decode and freed slots are refilled while others keep decoding

    class AuditEngine:
        """Delegating wrapper asserting slot hygiene on every transition."""

        def __init__(self, inner):
            self._e = inner
            self.in_use = set()

        def __getattr__(self, name):
            return getattr(self._e, name)

        def admit_many(self, requests):
            slots = self._e.admit_many(requests)
            assert len(set(slots)) == len(slots), f"burst reused a slot: {slots}"
            for slot in slots:
                assert slot not in self.in_use, f"slot {slot} double-booked"
                self.in_use.add(slot)
            return slots

        def fetch(self, slot, n_out):
            assert slot in self.in_use
            self.in_use.discard(slot)
            return self._e.fetch(slot, n_out)

    audit = AuditEngine(eng)
    comps = ContinuousScheduler(audit, clock=ManualClock(tick=0.3)).run(requests)
    assert sorted(c.rid for c in comps) == sorted(r.rid for r in requests)
    by_rid = {c.rid: c for c in comps}
    for r in requests:
        c = by_rid[r.rid]
        assert len(c.tokens) == r.max_new_tokens  # no EOS configured: full budget
        assert c.admitted >= r.arrival and c.finished >= c.admitted
    assert not audit.in_use
    assert sorted(eng.free_slots) == [0, 1, 2]  # no slot leak
    assert not bool(np.asarray(eng._state.active).any())
    assert eng.stats["evicted"] == eng.stats["admitted"] == len(requests)


# ---------------------------------------------------------------------------
# launch.serve argument audit


def test_serve_args_fail_fast():
    from repro.launch.serve import build_parser, validate_args
    from repro.config import get_arch

    parser = build_parser()
    dec = get_arch("smollm-135m")
    enc = get_arch("hubert-xlarge")

    with pytest.raises(SystemExit, match="encoder-only"):
        validate_args(parser.parse_args([]), enc)
    with pytest.raises(SystemExit, match="vlm"):
        validate_args(parser.parse_args([]), get_arch("phi-3-vision-4.2b"))
    validate_args(parser.parse_args(["--engine", "static"]), get_arch("phi-3-vision-4.2b"))
    with pytest.raises(SystemExit, match="multipod"):
        validate_args(parser.parse_args(["--mesh", "multipod"]), dec)
    with pytest.raises(SystemExit, match="max-slots"):
        validate_args(parser.parse_args(["--max-slots", "0"]), dec)
    with pytest.raises(SystemExit, match="gen"):
        validate_args(parser.parse_args(["--gen", "0"]), dec)
    validate_args(parser.parse_args([]), dec)  # defaults pass

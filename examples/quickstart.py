"""Quickstart: the whole one-shot-FL story in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Make a synthetic non-IID federation (3 clients, Dirichlet split).
2. Train each client locally (this is all that ever leaves a client).
3. Run Co-Boosting on the server: generator + ensemble reweighting +
   distillation — no data, no extra transmissions.
"""
from functools import partial

import jax

from repro.config.train import OFLConfig
from repro.core import default_image_setup, run_coboosting, uniform_weights
from repro.data import make_synth_images
from repro.fed import build_market, market_eval_fn
from repro.models.cnn import cnn_apply, init_cnn

CLASSES, SHAPE = 6, (16, 16, 3)

cfg = OFLConfig(
    num_clients=3, alpha=0.1,            # highly non-IID
    local_epochs=12, local_batch_size=32,
    epochs=12, gen_iters=8, batch_size=32, latent_dim=32, buffer_batches=3,
)

# --- federation + local training (client side) -----------------------------
x, y = make_synth_images(0, CLASSES, 120, SHAPE)
test_x, test_y = make_synth_images(1, CLASSES, 40, SHAPE)
applies, client_params, sizes, _ = build_market(0, x, y, cfg, CLASSES, archs=["cnn2"] * 3)

# --- server side: one communication round, then Co-Boosting ----------------
server_apply = partial(cnn_apply, "cnn2")
server_params = init_cnn(jax.random.key(7), "cnn2", CLASSES, SHAPE)
gen_apply, gen_params = default_image_setup(jax.random.key(5), cfg, CLASSES, SHAPE)
eval_fn = market_eval_fn(applies, client_params, server_apply, test_x, test_y)

print("before:", eval_fn(server_params, uniform_weights(cfg.num_clients)))
state = run_coboosting(
    applies, client_params, server_apply, server_params, gen_apply, gen_params,
    cfg, CLASSES, jax.random.key(0), eval_fn=eval_fn, eval_every=4,
)
print("after :", state.history[-1])
print("learned ensemble weights:", [round(float(w), 3) for w in state.weights])

"""Serving example on a reduced assigned arch: the fused static batch and the
continuous-batching engine generate the same greedy continuations — the
engine just never waits for a batch to fill and never syncs per token.

    PYTHONPATH=src python examples/serve_decode.py --arch jamba-v0.1-52b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced_variant
from repro.data import make_token_stream
from repro.models import init_lm
from repro.serve import ContinuousScheduler, EngineConfig, Request, ServeEngine, static_generate

p = argparse.ArgumentParser()
p.add_argument("--arch", default="jamba-v0.1-52b")
p.add_argument("--batch", type=int, default=2)
p.add_argument("--prompt", type=int, default=32)
p.add_argument("--gen", type=int, default=16)
args = p.parse_args()

cfg = reduced_variant(get_arch(args.arch)).replace(dtype="float32", param_dtype="float32")
if cfg.is_encoder_only:
    raise SystemExit(f"{cfg.name}: encoder-only, no decode (see DESIGN.md skips)")
if cfg.frontend == "vision":
    raise SystemExit(
        f"{cfg.name}: the continuous engine has no vision-prefix admission yet; "
        "see repro.launch.serve --engine static for the vlm path"
    )

params = init_lm(cfg, jax.random.key(0))
data = make_token_stream(0, cfg.vocab_size, args.batch, args.prompt)
tokens = data["tokens"][:, : args.prompt].astype(np.int32)

# static arm: prefill + full greedy decode in ONE dispatch, tokens
# accumulated on device (the legacy loop synced every token to host)
t0 = time.time()
static_out = np.asarray(static_generate(params, cfg, {"tokens": jnp.asarray(tokens)}, args.gen))
print(f"arch={cfg.name} family={cfg.family}")
print(f"static : {args.batch}x{args.gen} tokens in {time.time()-t0:.2f}s (1 dispatch)")

# continuous arm: same prompts through the slot engine, in BOTH KV layouts —
# the paged pool (pages + page table + flash-decode dispatch) must produce
# the same greedy tokens the dense per-slot rectangle does
page = 16
max_seq = -(-(args.prompt + args.gen) // page) * page
for layout in ("dense", "paged"):
    engine = ServeEngine(
        cfg, params,
        EngineConfig(max_slots=args.batch, max_seq=max_seq,
                     max_new=args.gen, decode_chunk=8,
                     kv_layout=layout, page_size=page),
    )
    t0 = time.time()
    completions = ContinuousScheduler(engine).run(
        [Request(rid=i, tokens=tokens[i], max_new_tokens=args.gen) for i in range(args.batch)]
    )
    pool = (f", pool {engine.pool.n_pages}x{engine.pool.page_size} tokens"
            if engine.pool is not None else "")
    print(f"engine : {layout:5s} {args.batch}x{args.gen} tokens in {time.time()-t0:.2f}s "
          f"({engine.stats['decode_chunks']} chunks, {engine.stats['host_syncs']} host syncs{pool})")
    match = all(np.array_equal(c.tokens, static_out[c.rid]) for c in completions)
    print(f"token parity static=={layout}-engine: {match}")
print("continuation[0]:", completions[0].tokens.tolist())

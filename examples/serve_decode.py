"""Batched serving example: prefill + greedy decode on a reduced assigned
arch, exercising the same lm_prefill / lm_decode programs the decode_32k /
long_500k dry-runs lower at production scale.

    PYTHONPATH=src python examples/serve_decode.py --arch jamba-v0.1-52b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced_variant
from repro.data import make_token_stream
from repro.models import init_lm, init_lm_state, lm_decode, lm_prefill

p = argparse.ArgumentParser()
p.add_argument("--arch", default="jamba-v0.1-52b")
p.add_argument("--batch", type=int, default=2)
p.add_argument("--prompt", type=int, default=32)
p.add_argument("--gen", type=int, default=16)
args = p.parse_args()

cfg = reduced_variant(get_arch(args.arch)).replace(dtype="float32", param_dtype="float32")
if cfg.is_encoder_only:
    raise SystemExit(f"{cfg.name}: encoder-only, no decode (see DESIGN.md skips)")

params = init_lm(cfg, jax.random.key(0))
data = make_token_stream(0, cfg.vocab_size, args.batch, args.prompt)
batch = {"tokens": jnp.asarray(data["tokens"])}
if cfg.family == "vlm":
    batch["prefix"] = jnp.asarray(
        np.random.RandomState(0).randn(args.batch, cfg.num_prefix_tokens, cfg.frontend_dim).astype(np.float32) * 0.02
    )

state = init_lm_state(cfg, args.batch, args.prompt + args.gen + cfg.num_prefix_tokens)
prefill = jax.jit(lambda p_, b, s: lm_prefill(p_, cfg, b, s))
decode = jax.jit(lambda p_, t, s, pos: lm_decode(p_, cfg, t, s, pos))

logits, state = prefill(params, batch, state)
tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
out = [np.asarray(tok)]
t0 = time.time()
base = args.prompt + cfg.num_prefix_tokens
for i in range(args.gen - 1):
    logits, state = decode(params, tok, state, jnp.asarray(base + i, jnp.int32))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out.append(np.asarray(tok))
jax.block_until_ready(tok)
print(f"arch={cfg.name} family={cfg.family}")
print(f"decoded {args.batch}×{args.gen} tokens in {time.time()-t0:.2f}s")
print("continuation[0]:", np.concatenate(out, 1)[0].tolist())

"""Co-Boosting as a framework feature: distill an ensemble of LM clients
into a server LM — the paper's technique at the substrate the assigned
architectures live in (DESIGN.md §4: stacked clients, embedding-space
generator, EE on final-position logits).

    PYTHONPATH=src python examples/distill_llm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig, get_arch, reduced_variant
from repro.core.distributed import dhs_embeds, ee_update_lm, ensemble_lm_logits
from repro.models import init_lm
from repro.runtime import make_distill_step_lm
from repro.utils import tree_stack

K = 3  # clients
cfg = reduced_variant(get_arch("smollm-135m")).replace(dtype="float32", param_dtype="float32")

# "pre-trained" clients (random init stands in for the model market upload)
clients = tree_stack([init_lm(cfg, jax.random.key(i)) for i in range(K)])
server = init_lm(cfg, jax.random.key(42))
w = jnp.full((K,), 1.0 / K)

tc = TrainConfig(optimizer="sgdm", learning_rate=0.05)
step = make_distill_step_lm(cfg, tc, temperature=4.0)
opt_state = step.optimizer.init(server)
jit_step = jax.jit(step)

B, S = 4, 32
key = jax.random.key(0)
for epoch in range(8):
    key, k1, k2, k3 = jax.random.split(key, 4)
    # embedding-space synthetic batch (generator stand-in: random draws)
    batch = {"embeds": jax.random.normal(k1, (B, S, cfg.d_model)) * 0.02}
    # DHS: make the batch hard for the ensemble (Eq. 10, embedding space)
    batch = dhs_embeds(clients, cfg, batch, w, k2, epsilon=0.05)
    # EE: reweight clients on the hard batch (Eq. 12)
    labels = jax.random.randint(k3, (B,), 0, cfg.vocab_size)
    w = ee_update_lm(w, clients, cfg, batch, labels, mu=0.1 / K)
    # Distill (Eq. 4)
    server, opt_state, metrics = jit_step(server, opt_state, clients, w, batch, jnp.asarray(epoch))
    print(f"epoch {epoch}: kd={float(metrics['kd']):.4f} w={np.round(np.asarray(w), 3)}")

print("done — server now approximates the weighted client ensemble.")

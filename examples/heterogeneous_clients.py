"""Model-market heterogeneity (paper Table 3): every client a different
architecture — LeNet, CNN, ResNet-style, MLP — distilled into a ResNet-
family server. FedAvg is impossible here; Co-Boosting does not care, since
it only touches logits.

    PYTHONPATH=src python examples/heterogeneous_clients.py
"""
from functools import partial

import jax

from repro.config.train import OFLConfig
from repro.core import default_image_setup, run_coboosting, uniform_weights
from repro.data import make_synth_images
from repro.fed import build_market, market_eval_fn
from repro.models.cnn import cnn_apply, init_cnn

CLASSES, SHAPE = 6, (16, 16, 3)
CLIENT_ARCHS = ["cnn5", "cnn2", "miniresnet", "mlp"]

cfg = OFLConfig(
    num_clients=len(CLIENT_ARCHS), alpha=0.1,
    local_epochs=12, local_batch_size=32,
    epochs=10, gen_iters=8, batch_size=32, latent_dim=32, buffer_batches=3,
)

x, y = make_synth_images(0, CLASSES, 120, SHAPE)
test_x, test_y = make_synth_images(1, CLASSES, 40, SHAPE)
applies, client_params, sizes, _ = build_market(0, x, y, cfg, CLASSES, archs=CLIENT_ARCHS)

server_apply = partial(cnn_apply, "miniresnet")
server_params = init_cnn(jax.random.key(7), "miniresnet", CLASSES, SHAPE)
gen_apply, gen_params = default_image_setup(jax.random.key(5), cfg, CLASSES, SHAPE)
eval_fn = market_eval_fn(applies, client_params, server_apply, test_x, test_y)

state = run_coboosting(
    applies, client_params, server_apply, server_params, gen_apply, gen_params,
    cfg, CLASSES, jax.random.key(0), eval_fn=eval_fn, eval_every=5,
)
print("final:", state.history[-1])
print("per-arch weights:", {a: round(float(w), 3) for a, w in zip(CLIENT_ARCHS, state.weights)})

"""Deterministic synthetic datasets.

No real datasets ship in this container (DESIGN.md §6), so the paper's
image experiments run on *SynthDigits*: a class-separable image distribution
where each class is a distinct oriented grating + color blob, perturbed per
sample by shifts and noise. Small CNNs reach >90% centralized accuracy on
it, Dirichlet partitions make it properly non-IID, and every qualitative
ordering the paper claims (Table 1/4/5/6/7) can be validated on it.

Token streams for the LM substrate come from a seeded hidden-Markov
generator (so next-token prediction is learnable, not uniform noise).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_synth_images(
    seed: int,
    num_classes: int,
    n_per_class: int,
    shape: Tuple[int, int, int] = (32, 32, 3),
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images in [-1,1] NHWC float32, labels int32), shuffled."""
    rng = np.random.RandomState(seed)
    h, w, c = shape
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32) / max(h, w)
    xs, ys = [], []
    for cls in range(num_classes):
        angle = np.pi * cls / num_classes
        freq = 4.0 + 3.0 * (cls % 4)
        phase_dir = np.cos(angle) * xx + np.sin(angle) * yy
        grating = np.sin(2 * np.pi * freq * phase_dir)  # (h, w)
        # class-dependent color mixing
        color = np.array(
            [np.cos(2 * np.pi * cls / num_classes + k * 2.1) for k in range(c)],
            np.float32,
        )
        # class-dependent blob position
        cy, cx = (0.25 + 0.5 * ((cls * 7) % num_classes) / num_classes), (
            0.25 + 0.5 * ((cls * 3) % num_classes) / num_classes
        )
        blob = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / 0.02))
        base = grating[..., None] * color[None, None] * 0.6 + blob[..., None] * 0.8
        for _ in range(n_per_class):
            img = base.copy()
            # per-sample jitter: roll + noise + contrast
            img = np.roll(img, rng.randint(-3, 4), axis=0)
            img = np.roll(img, rng.randint(-3, 4), axis=1)
            img = img * (0.8 + 0.4 * rng.rand()) + rng.randn(h, w, c).astype(np.float32) * 0.15
            xs.append(np.clip(img, -1.0, 1.0))
            ys.append(cls)
    x = np.stack(xs).astype(np.float32)
    y = np.asarray(ys, np.int32)
    order = rng.permutation(len(y))
    return x[order], y[order]


def make_token_stream(
    seed: int, vocab: int, batch: int, seq_len: int, num_states: int = 8
) -> Dict[str, np.ndarray]:
    """Hidden-Markov token batches: state transitions are deterministic-ish,
    each state emits from a distinct vocab slice — next-token prediction is
    learnable well below the uniform-entropy floor."""
    rng = np.random.RandomState(seed)
    trans = rng.dirichlet(np.ones(num_states) * 0.3, size=num_states)
    slice_w = max(vocab // num_states, 1)
    tokens = np.zeros((batch, seq_len + 1), np.int64)
    for b in range(batch):
        s = rng.randint(num_states)
        for t in range(seq_len + 1):
            tokens[b, t] = (s * slice_w + rng.zipf(1.5) - 1) % vocab
            s = rng.choice(num_states, p=trans[s])
    return {
        "tokens": tokens[:, :-1].astype(np.int32),
        "labels": tokens[:, 1:].astype(np.int32),
    }


def make_lm_distill_batch(
    seed: int, batch: int, seq_len: int, d_model: int, vocab: int
) -> Dict[str, np.ndarray]:
    """Embedding-space synthetic batch for the LM-scale distillation path:
    embeds (B, S, d) + target-token labels (B,) for the EE weight search."""
    rng = np.random.RandomState(seed)
    return {
        "embeds": rng.randn(batch, seq_len, d_model).astype(np.float32) * 0.02,
        "targets": rng.randint(0, vocab, size=(batch,)).astype(np.int32),
    }

from repro.data.synthetic import (
    make_synth_images,
    make_token_stream,
    make_lm_distill_batch,
)
from repro.data.partitions import (
    dirichlet_partition,
    c_cls_partition,
    iid_partition,
    lognormal_resize,
    partition_dataset,
)
from repro.data.loader import batch_iterator, shuffle_arrays

__all__ = [
    "make_synth_images",
    "make_token_stream",
    "make_lm_distill_batch",
    "dirichlet_partition",
    "c_cls_partition",
    "iid_partition",
    "lognormal_resize",
    "partition_dataset",
    "batch_iterator",
    "shuffle_arrays",
]

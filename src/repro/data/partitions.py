"""Federated partition schemes used by the paper's experiments.

* ``dirichlet_partition`` — p_k ~ Dir(α) per class (Table 1; smaller α ⇒
  more skew).
* ``c_cls_partition``     — each client holds only C of the classes
  (Table 5).
* ``lognormal_resize``    — unbalance client sizes by lognormal draws
  (Table 4 / Fig. 2).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def dirichlet_partition(
    seed: int, labels: np.ndarray, n_clients: int, alpha: float, min_size: int = 2
) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    for _ in range(100):
        idx_per_client: List[List[int]] = [[] for _ in range(n_clients)]
        for cls in range(n_classes):
            idx = np.where(labels == cls)[0]
            rng.shuffle(idx)
            p = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(p) * len(idx)).astype(int)[:-1]
            for k, part in enumerate(np.split(idx, cuts)):
                idx_per_client[k].extend(part.tolist())
        sizes = [len(ix) for ix in idx_per_client]
        if min(sizes) >= min_size:
            break
    return [np.asarray(sorted(ix), np.int64) for ix in idx_per_client]


def c_cls_partition(
    seed: int, labels: np.ndarray, n_clients: int, c: int
) -> List[np.ndarray]:
    """Each client holds at most C distinct classes (hard invariant).
    Classes are dealt round-robin so coverage is maximal when
    n_clients·C ≥ n_classes (the paper's setting); with fewer total slots,
    uncovered classes' samples are dropped rather than violating the
    limit."""
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    c = min(c, n_classes)
    client_classes: List[List[int]] = [[] for _ in range(n_clients)]
    order = [int(v) for v in rng.permutation(n_classes)]
    ptr = 0
    for _ in range(n_clients * c):
        placed = False
        for _ in range(n_classes):
            cls = order[ptr % n_classes]
            ptr += 1
            ks = [
                k
                for k in range(n_clients)
                if len(client_classes[k]) < c and cls not in client_classes[k]
            ]
            if ks:
                k = min(ks, key=lambda k_: len(client_classes[k_]))
                client_classes[k].append(cls)
                placed = True
                break
        if not placed:
            break
    owners = {
        cls: [k for k in range(n_clients) if cls in client_classes[k]]
        for cls in range(n_classes)
    }
    idx_per_client: List[List[int]] = [[] for _ in range(n_clients)]
    for cls in range(n_classes):
        own = owners[cls]
        if not own:
            continue  # uncovered class (only when n·C < classes)
        idx = np.where(labels == cls)[0]
        rng.shuffle(idx)
        for k, part in zip(own, np.array_split(idx, len(own))):
            idx_per_client[k].extend(part.tolist())
    return [np.asarray(sorted(ix), np.int64) for ix in idx_per_client]


def iid_partition(seed: int, labels: np.ndarray, n_clients: int) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(labels))
    return [np.asarray(sorted(p), np.int64) for p in np.array_split(idx, n_clients)]


def lognormal_resize(
    seed: int, parts: List[np.ndarray], sigma: float
) -> List[np.ndarray]:
    """Subsample each client's shard so sizes follow a lognormal profile."""
    if sigma <= 0:
        return parts
    rng = np.random.RandomState(seed)
    draws = rng.lognormal(mean=0.0, sigma=sigma, size=len(parts))
    draws = draws / draws.max()
    out = []
    for part, frac in zip(parts, draws):
        n = max(2, int(len(part) * frac))
        out.append(part[rng.permutation(len(part))[:n]])
    return out


def partition_dataset(
    seed: int,
    labels: np.ndarray,
    cfg,
) -> List[np.ndarray]:
    """Dispatch on OFLConfig.partition."""
    if cfg.partition == "dirichlet":
        parts = dirichlet_partition(seed, labels, cfg.num_clients, cfg.alpha)
    elif cfg.partition == "c_cls":
        parts = c_cls_partition(seed, labels, cfg.num_clients, cfg.c_cls)
    elif cfg.partition == "iid":
        parts = iid_partition(seed, labels, cfg.num_clients)
    else:
        raise ValueError(f"unknown partition {cfg.partition!r}")
    return lognormal_resize(seed + 1, parts, cfg.lognormal_sigma)

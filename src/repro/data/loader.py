"""Minimal numpy batch iteration (host-side; device transfer happens at jit
boundaries)."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def shuffle_arrays(seed: int, *arrays: np.ndarray) -> Tuple[np.ndarray, ...]:
    rng = np.random.RandomState(seed)
    order = rng.permutation(len(arrays[0]))
    return tuple(a[order] for a in arrays)


def batch_iterator(
    x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0, epochs: int = 1, drop_last: bool = False
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    n = len(x)
    for e in range(epochs):
        xs, ys = shuffle_arrays(seed + e, x, y)
        stop = n - (n % batch_size) if drop_last else n
        for i in range(0, stop, batch_size):
            yield xs[i : i + batch_size], ys[i : i + batch_size]

"""Divisibility-aware parameter/activation partitioning.

The framework uses *logical* axis names in rules and resolves them against
whatever mesh is in context:

=============  =====================================================
logical axis   mesh axes it maps to
=============  =====================================================
``batch``      ``("pod", "data")`` — data parallel (pod folds in)
``fsdp``       ``("pod", "data")`` — fully-sharded parameter dim
``tp``         ``("model",)``     — tensor-parallel dim
``experts``    ``("model",)``     — expert-parallel dim (MoE)
``seq``        ``("model",)``     — sequence-sharded KV cache (decode)
=============  =====================================================

Resolution checks divisibility of the array dim against the mesh-axis-size
product; when it does not divide, it retries progressively smaller axis
subsets and finally falls back to replication. This single mechanism is what
lets one rule set serve smollm's 9 heads and qwen3's 64 heads, mixtral's 8
experts and qwen3-moe's 128, granite's 49155 vocab and qwen's 151936.

Rules are matched on parameter *path suffixes*. Parameters may carry extra
leading dims (a scan-over-layers ``L`` dim, a stacked-clients ``K`` dim for
the Co-Boosting ensemble); those are padded with ``None`` automatically.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.utils.trees import tree_map_with_path

LogicalSpec = Tuple[Optional[str], ...]

_LOGICAL_TO_MESH: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    # stacked client axis of a grouped ClientBank (core/client_bank.py):
    # clients within a homogeneous group data-parallelize across the mesh
    "clients": ("pod", "data"),
    "tp": ("model",),
    "experts": ("model",),
    "seq": ("model",),
    "heads": ("model",),
    "vocab": ("model",),
}

# (path regex, [candidate logical specs in preference order])
LOGICAL_RULES: List[Tuple[str, List[LogicalSpec]]] = [
    (r"embed/table$", [("vocab", "fsdp"), (None, "fsdp")]),
    (r"lm_head/kernel$", [("fsdp", "vocab"), ("fsdp", None)]),
    (r"pred_head/kernel$", [("fsdp", "vocab"), ("fsdp", None)]),
    # attention
    (r"attn/w[qkv]$", [("fsdp", "heads", None), ("fsdp", None, None)]),
    (r"attn/wo$", [("heads", None, "fsdp"), (None, None, "fsdp")]),
    (r"attn/[qk]_norm$", [(None,)]),
    # dense MLP
    (r"mlp/w[ig]$", [("fsdp", "tp")]),
    (r"mlp/wo$", [("tp", "fsdp")]),
    # MoE
    (r"moe/router$", [("fsdp", None)]),
    (r"moe/w[ig]$", [("experts", "fsdp", None), (None, "fsdp", "tp")]),
    (r"moe/wo$", [("experts", None, "fsdp"), (None, "tp", "fsdp")]),
    # mamba
    (r"mamba/in_proj$", [("fsdp", "tp")]),
    (r"mamba/conv$", [(None, "tp")]),
    (r"mamba/x_proj$", [("tp", None)]),
    (r"mamba/dt_proj$", [(None, "tp")]),
    (r"mamba/A_log$", [("tp", None)]),
    (r"mamba/D$", [("tp",)]),
    (r"mamba/out_proj$", [("tp", "fsdp")]),
    # xlstm
    (r"xlstm/in_proj$", [("fsdp", "tp")]),
    (r"xlstm/w[qkv]$", [("fsdp", "heads", None), ("fsdp", None, None)]),
    (r"xlstm/gates$", [("fsdp", None)]),
    (r"xlstm/out_proj$", [("tp", "fsdp")]),
    (r"xlstm/r[zifo]$", [("heads", None, None), (None, None, None)]),
    # vision / audio frontend projector stubs
    (r"projector/kernel$", [("fsdp", "tp")]),
    # norms, biases, scalars
    (r"(scale|bias|b)$", [(None,)]),
]


def _mesh_axes() -> Dict[str, int]:
    if hasattr(jax.sharding, "get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()
    else:  # jax < 0.5: only the thread-local physical mesh context exists
        from jax._src import mesh as mesh_lib

        mesh = mesh_lib.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return {}
    return dict(mesh.shape)


def resolve_rule(
    logical: LogicalSpec,
    shape: Sequence[int],
    mesh_axes: Dict[str, int],
) -> P:
    """Resolve one logical spec against a concrete shape + mesh.

    For each dim, keep the largest prefix-product of candidate mesh axes that
    divides the dim size; axes already used by an earlier dim are skipped
    (a mesh axis may appear at most once in a PartitionSpec).
    """
    used: set = set()
    out: List[Any] = []
    ndims = len(shape)
    # pad leading Nones for stacked/scanned extra dims
    spec = (None,) * (ndims - len(logical)) + tuple(logical)
    for dim, name in zip(shape, spec):
        if name is None:
            out.append(None)
            continue
        cands = [a for a in _LOGICAL_TO_MESH[name] if a in mesh_axes and a not in used]
        chosen: List[str] = []
        prod = 1
        for a in cands:
            if dim % (prod * mesh_axes[a]) == 0:
                chosen.append(a)
                prod *= mesh_axes[a]
        if not chosen:
            out.append(None)
        else:
            used.update(chosen)
            out.append(tuple(chosen) if len(chosen) > 1 else chosen[0])
    return P(*out)


def logical_to_pspec(logical: LogicalSpec, shape: Sequence[int]) -> P:
    return resolve_rule(logical, shape, _mesh_axes())


def _match(path: str) -> Optional[List[LogicalSpec]]:
    for pattern, candidates in LOGICAL_RULES:
        if re.search(pattern, path):
            return candidates
    return None


def _score(spec: P) -> int:
    n = 0
    for s in spec:
        if s is None:
            continue
        n += len(s) if isinstance(s, tuple) else 1
    return n


def infer_param_specs(params: Any, mesh_axes: Optional[Dict[str, int]] = None) -> Any:
    """Build a PartitionSpec tree for a param tree (of arrays or
    ShapeDtypeStructs). Resolves against the mesh currently in context
    unless ``mesh_axes`` is given explicitly — a fleet shards each replica
    against ITS mesh slice without entering N global mesh contexts."""
    mesh_axes = _mesh_axes() if mesh_axes is None else dict(mesh_axes)

    def infer(path: str, leaf) -> P:
        if not mesh_axes:
            return P()
        candidates = _match(path)
        if candidates is None:
            return P(*([None] * len(leaf.shape)))
        best = None
        for logical in candidates:
            spec = resolve_rule(logical, leaf.shape, mesh_axes)
            if best is None or _score(spec) > _score(best):
                best = spec
        return best

    return tree_map_with_path(infer, params)


def batch_pspec(batch_size: int, extra_dims: int = 1) -> P:
    """PartitionSpec for a batched activation: shard dim0 over data axes if
    divisible, remaining dims replicated."""
    mesh_axes = _mesh_axes()
    if not mesh_axes:
        return P()
    spec = resolve_rule(("batch",), (batch_size,), mesh_axes)
    return P(spec[0], *([None] * extra_dims))


def activation_pspec(shape: Sequence[int], logical: LogicalSpec) -> P:
    return resolve_rule(logical, shape, _mesh_axes())


_STATE_RULES: List[Tuple[str, LogicalSpec]] = [
    # attention KV cache (G, B, S, K, hd): batch over data, seq over model
    (r"/(k|v)$", (None, "batch", "seq", None, None)),
    # mamba conv tail (G, B, K-1, inner) and state h (G, B, inner, N)
    (r"/conv$", (None, "batch", None, "tp")),
    (r"/h$", (None, "batch", "tp", None)),
    # mLSTM / sLSTM per-head states
    (r"/C$", (None, "batch", "heads", None, None)),
    (r"/(n|c)$", (None, "batch", "heads", None)),
    (r"/m$", (None, "batch", "heads")),
]


def decode_state_specs(state: Any, mesh_axes: Optional[Dict[str, int]] = None) -> Any:
    """PartitionSpec tree for a decode/prefill state pytree (KV caches are
    sequence-sharded over the model axis; SSM states channel-sharded)."""
    mesh_axes = _mesh_axes() if mesh_axes is None else dict(mesh_axes)

    def infer(path: str, leaf) -> P:
        if not mesh_axes:
            return P()
        for pattern, logical in _STATE_RULES:
            if re.search(pattern, path):
                spec = logical[-leaf.ndim :] if len(logical) >= leaf.ndim else logical
                return resolve_rule(spec, leaf.shape, mesh_axes)
        return P(*([None] * leaf.ndim))

    return tree_map_with_path(infer, state)


# Serving-engine state (repro.serve.engine.DecodeState). Unlike the
# training/prefill state above, the batch dim here is SLOTS — requests land
# on arbitrary slots at arbitrary times, so the slot dim stays replicated
# and parallelism comes from the heads/channel dims (tensor-parallel decode:
# every model shard serves every slot, holding only its heads' pages).
_ENGINE_STATE_RULES: List[Tuple[str, LogicalSpec]] = [
    # paged KV pools (G, pool_pages, page, KH, hd): heads over the model
    # axis — each shard holds EVERY page's slice of ITS kv-heads, so page
    # ids (and the host free list) stay global and the handoff scatter is
    # shard-local. Never shard the page dim: ids are data, not layout.
    (r"/(k|v)_pages$", (None, None, None, "heads", None)),
    # dense engine KV (G, slots, cache_len, KH, hd): same heads split
    (r"/(k|v)$", (None, None, None, "heads", None)),
    # recurrent carries, per-slot dense: channel-sharded like training state
    (r"/conv$", (None, None, None, "tp")),
    (r"/h$", (None, None, "tp", None)),
    (r"/C$", (None, None, "heads", None, None)),
    (r"/(n|c)$", (None, None, "heads", None)),
    (r"/m$", (None, None, "heads")),
]


def shard_engine_state(state: Any, mesh_axes: Optional[Dict[str, int]] = None) -> Any:
    """PartitionSpec tree for a serving-engine ``DecodeState``: KV page
    pools / dense caches sharded along the heads axis, recurrent carries
    channel-sharded, and every slot-bookkeeping leaf (positions, budgets,
    output rows, page tables, rng) replicated — the host mutates those by
    slot id and the numbers must read the same from every shard.

    The rules match on path SUFFIXES, so they apply to any pytree that
    nests a cache under an extra prefix — the speculative drafter's dense
    state (wrapped as ``{"draft": ...}`` by ``SpecDecoder.reset``) picks up
    the same ``/k``, ``/v`` heads split as the target's dense engine
    state without a drafter-specific rule."""
    mesh_axes = _mesh_axes() if mesh_axes is None else dict(mesh_axes)

    def infer(path: str, leaf) -> P:
        if not mesh_axes or leaf.ndim == 0:
            return P()
        for pattern, logical in _ENGINE_STATE_RULES:
            if re.search(pattern, path):
                spec = logical[-leaf.ndim :] if len(logical) >= leaf.ndim else logical
                return resolve_rule(spec, leaf.shape, mesh_axes)
        return P(*([None] * leaf.ndim))

    return tree_map_with_path(infer, state)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Sharding-constrain an activation by logical axis names. No-op when no
    mesh is in context (unit tests / single-device runs)."""
    mesh_axes = _mesh_axes()
    if not mesh_axes:
        return x
    spec = resolve_rule(tuple(logical), x.shape, mesh_axes)
    return jax.lax.with_sharding_constraint(x, spec)

from repro.sharding.partition import (
    LOGICAL_RULES,
    constrain,
    infer_param_specs,
    logical_to_pspec,
    resolve_rule,
    batch_pspec,
    activation_pspec,
    decode_state_specs,
    shard_engine_state,
)

__all__ = [
    "LOGICAL_RULES",
    "constrain",
    "infer_param_specs",
    "logical_to_pspec",
    "resolve_rule",
    "batch_pspec",
    "activation_pspec",
    "decode_state_specs",
    "shard_engine_state",
]

"""Single source of truth for every component's metric names.

Each serving/training component used to reset its own stats dict by hand
(``_fresh_stats()`` in the engine, the router's inline ``{"routed": 0, ...}``)
— two hand-maintained key sets that could silently drift. Components now
declare their **local key → namespaced metric name** schema here, build a
:class:`repro.obs.registry.StatsView` from it, and a test
(``tests/test_obs.py::test_serve_namespace_matches_smoke_run``) asserts that
what a smoke run actually increments is exactly what this module declares.

Namespace glossary (see README "Observability" for the prose version):

* ``serve.admit.*``    — request admission (cold prefill or spliced)
* ``serve.prefill.*``  — bucketed prefill dispatches/tokens
* ``serve.handoff.*``  — sealed prefill→decode handoffs (disagg seam)
* ``serve.decode.*``   — decode chunks and the once-per-chunk host syncs
* ``serve.slots.*``    — slot lifecycle
* ``serve.kv.*``       — page pool traffic (allocs, appends, CoW, resets)
* ``serve.prefix.*``   — radix prefix cache hits/splices
* ``serve.spec.*``     — speculative draft/verify counters
* ``serve.router.*``   — fleet routing decisions
* ``serve.request.*``  — per-request latency breakdown (TTFT, queue wait)
* ``ofl.*``            — training pipeline phases (generator boost, DHS,
  EE weight search, KD distillation, fused epoch driver)
"""
from __future__ import annotations

# -- serving engine (ServeEngine / PrefillWorker / DecodeWorker) -------------
# Local keys are the historical stats-dict keys; metric names are the stable
# export namespace. Adding an engine counter means adding it HERE (the
# engine's StatsView rejects unknown keys).
SERVE_ENGINE_METRICS = {
    "admitted": "serve.admit.requests",
    "prefill_dispatches": "serve.prefill.dispatches",
    "prefill_tokens": "serve.prefill.tokens",
    "handoffs": "serve.handoff.count",
    "decode_chunks": "serve.decode.chunks",
    "host_syncs": "serve.decode.host_syncs",
    "evicted": "serve.slots.evicted",
    "page_appends": "serve.kv.page_appends",
    "pages_allocated": "serve.kv.pages_allocated",
    "table_resets": "serve.kv.table_resets",
    # radix prefix cache (serve/prefix_cache.py)
    "prefix_hits": "serve.prefix.hits",
    "spliced_admissions": "serve.prefix.spliced_admissions",
    "spliced_pages": "serve.prefix.spliced_pages",
    "cow_copies": "serve.kv.cow_copies",
    # speculative decoding (serve/spec_decode.py)
    "spec_steps": "serve.spec.steps",
    "draft_proposed": "serve.spec.draft_proposed",
    "draft_accepted": "serve.spec.draft_accepted",
}

# -- fleet router (serve/scheduler.py) ---------------------------------------
ROUTER_METRICS = {
    "routed": "serve.router.routed",
    "requeued": "serve.router.requeued",
    "affinity_hits": "serve.router.affinity_hits",
}

# -- KV pool / prefix cache occupancy gauges (published at snapshot time) ----
KV_GAUGES = {
    "free_pages": "serve.kv.free_pages",
    "pages_in_use": "serve.kv.pages_in_use",
    "capacity_pages": "serve.kv.capacity_pages",
    "reclaimable_pages": "serve.prefix.reclaimable_pages",
}

# -- per-request latency histograms (serve/metrics.py definitions) -----------
REQUEST_HISTOGRAMS = (
    "serve.request.latency_s",
    "serve.request.queue_wait_s",
    "serve.request.ttft_s",
)

# -- training pipeline (core/coboosting.py + core/epoch.py drivers) ----------
OFL_METRICS = {
    "epochs": "ofl.epoch.count",
    "epoch_dispatches": "ofl.epoch.dispatches",
    "gen_steps": "ofl.gen.steps",
    "ee_steps": "ofl.ee.steps",
    "kd_steps": "ofl.kd.steps",
}

# phase wall-time histograms (seconds); the fused driver can only time the
# whole single-dispatch epoch (phases are inside one jitted program — the
# in-program split shows up in a --profile-dir XLA trace via named_scope)
OFL_HISTOGRAMS = (
    "ofl.epoch.step_s",
    "ofl.gen.step_s",
    "ofl.ee.step_s",
    "ofl.kd.step_s",
)

#: Metric names a paged continuous-serving smoke run MUST increment — the
#: drift guard's floor (and repro.obs.validate's required-key set).
REQUIRED_SERVE_KEYS = (
    "serve.admit.requests",
    "serve.prefill.dispatches",
    "serve.prefill.tokens",
    "serve.decode.chunks",
    "serve.decode.host_syncs",
    "serve.slots.evicted",
    "serve.kv.pages_allocated",
)


def serve_namespace() -> frozenset:
    """Every declared serve.* metric name (counters + gauges + request
    histograms) — the universe a serving run is allowed to touch."""
    return frozenset(
        list(SERVE_ENGINE_METRICS.values())
        + list(ROUTER_METRICS.values())
        + list(KV_GAUGES.values())
        + list(REQUEST_HISTOGRAMS)
    )

"""Process-wide metrics registry: counters, gauges and histograms under
stable dotted key namespaces (``serve.prefill.dispatches``,
``ofl.kd.step_s``) with an optional labels dimension (``replica=0``,
``arch=cnn2``) so fleet runs aggregate cleanly.

The registry replaces the free-floating per-component ``stats`` dicts that
used to live in :class:`repro.serve.engine.ServeEngine`, the KV pool and the
router: each component now declares its metric names ONCE (in
:mod:`repro.obs.names`) and mutates them through a :class:`StatsView` — a
dict-shaped adapter that keeps the old ``stats["admitted"] += 1`` call sites
(and every test written against them) working verbatim while the values land
in namespaced, labelled registry series.

Cost model: a counter bump is one dict update — exactly what the old stats
dicts paid — so components keep their registries ALWAYS on. A registry
constructed with ``enabled=False`` (the process-global default until a
launcher passes ``--metrics-out``) turns ``inc``/``observe``/``set_gauge``
into an attribute check + early return, so instrumenting a hot path costs
nothing when nobody is collecting.

Export shapes:

* :meth:`MetricsRegistry.snapshot` — list of plain-dict records (one per
  labelled series; histograms carry count/sum/percentiles), JSONL-ready;
* :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition format
  (dots mangled to underscores, labels rendered inline);
* :meth:`MetricsRegistry.dump` — both files in one call, the shape the CI
  smoke lanes upload and ``repro.obs.validate`` checks.
"""
from __future__ import annotations

import json
import os
import threading
from collections.abc import MutableMapping
from typing import Dict, Iterator, List, Mapping, Optional, Tuple

import numpy as np

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Mapping[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _prom_labels(labels: LabelKey) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class MetricsRegistry:
    """Counters, gauges and histograms keyed by (dotted name, label set).

    Thread-safe for the cheap mutators (the serving fleet's router loop and a
    background drain may both bump counters); snapshots are taken under the
    same lock.
    """

    def __init__(self, enabled: bool = True, hist_capacity: int = 4096):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._hist_capacity = hist_capacity
        self._counters: Dict[str, Dict[LabelKey, float]] = {}
        self._gauges: Dict[str, Dict[LabelKey, float]] = {}
        self._hists: Dict[str, Dict[LabelKey, List[float]]] = {}

    # -- mutators ------------------------------------------------------------

    def inc(self, name: str, value: float = 1, **labels) -> None:
        """Add ``value`` to a counter series (created at 0 on first touch)."""
        if not self.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            series = self._counters.setdefault(name, {})
            series[key] = series.get(key, 0) + value

    def set_counter(self, name: str, value: float, **labels) -> None:
        """Overwrite a counter series — the cumulative-mirror idiom
        (``spec_decode.sync`` assigns device counter readbacks rather than
        incrementing)."""
        if not self.enabled:
            return
        with self._lock:
            self._counters.setdefault(name, {})[_label_key(labels)] = value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._gauges.setdefault(name, {})[_label_key(labels)] = value

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one histogram observation (ring-bounded at
        ``hist_capacity`` samples per labelled series)."""
        if not self.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            samples = self._hists.setdefault(name, {}).setdefault(key, [])
            samples.append(float(value))
            if len(samples) > self._hist_capacity:
                del samples[: len(samples) - self._hist_capacity]

    def reset(self) -> None:
        """Zero every series (names and labels are forgotten, not kept at 0:
        a snapshot after reset reports only what actually happened since)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    # -- readers -------------------------------------------------------------

    def value(self, name: str, **labels) -> float:
        """One counter/gauge series' current value (0 if never touched)."""
        key = _label_key(labels)
        with self._lock:
            if name in self._counters:
                return self._counters[name].get(key, 0)
            return self._gauges.get(name, {}).get(key, 0)

    def total(self, name: str) -> float:
        """A counter summed across every label set — the fleet aggregate."""
        with self._lock:
            return sum(self._counters.get(name, {}).values())

    def names(self, prefix: str = "") -> List[str]:
        """Every metric name touched so far (optionally prefix-filtered)."""
        with self._lock:
            all_names = set(self._counters) | set(self._gauges) | set(self._hists)
        return sorted(n for n in all_names if n.startswith(prefix))

    def snapshot(self) -> List[dict]:
        """JSONL-ready records, one per labelled series, sorted by name so
        diffs between runs are stable."""
        out: List[dict] = []
        with self._lock:
            for name in sorted(self._counters):
                for key, val in sorted(self._counters[name].items()):
                    out.append(
                        {"name": name, "type": "counter", "labels": dict(key), "value": val}
                    )
            for name in sorted(self._gauges):
                for key, val in sorted(self._gauges[name].items()):
                    out.append(
                        {"name": name, "type": "gauge", "labels": dict(key), "value": val}
                    )
            for name in sorted(self._hists):
                for key, samples in sorted(self._hists[name].items()):
                    xs = np.asarray(samples, np.float64)
                    out.append(
                        {
                            "name": name,
                            "type": "histogram",
                            "labels": dict(key),
                            "count": int(xs.size),
                            "sum": float(xs.sum()),
                            "min": float(xs.min()) if xs.size else 0.0,
                            "max": float(xs.max()) if xs.size else 0.0,
                            "p50": float(np.percentile(xs, 50)) if xs.size else 0.0,
                            "p95": float(np.percentile(xs, 95)) if xs.size else 0.0,
                        }
                    )
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format. Histograms export as summary
        quantiles plus ``_count``/``_sum`` (enough for a scrape/pushgateway
        bridge without carrying raw samples)."""
        lines: List[str] = []
        for rec in self.snapshot():
            pname = _prom_name(rec["name"])
            labels = _label_key(rec["labels"])
            if rec["type"] in ("counter", "gauge"):
                lines.append(f"# TYPE {pname} {rec['type']}")
                lines.append(f"{pname}{_prom_labels(labels)} {rec['value']}")
                continue
            lines.append(f"# TYPE {pname} summary")
            for q, field in (("0.5", "p50"), ("0.95", "p95")):
                qlabels = labels + (("quantile", q),)
                lines.append(f"{pname}{_prom_labels(qlabels)} {rec[field]}")
            lines.append(f"{pname}_count{_prom_labels(labels)} {rec['count']}")
            lines.append(f"{pname}_sum{_prom_labels(labels)} {rec['sum']}")
        return "\n".join(lines) + ("\n" if lines else "")

    def dump(self, jsonl_path: str, prom_path: Optional[str] = None) -> None:
        """Write the JSONL snapshot (and, by default, a ``.prom`` sibling in
        Prometheus text format) — the artifact pair the CI lanes upload."""
        with open(jsonl_path, "w") as f:
            for rec in self.snapshot():
                f.write(json.dumps(rec) + "\n")
        if prom_path is None:
            prom_path = os.path.splitext(jsonl_path)[0] + ".prom"
        with open(prom_path, "w") as f:
            f.write(self.to_prometheus())

    # -- component adapters --------------------------------------------------

    def view(self, schema: Mapping[str, str], **labels) -> "StatsView":
        """A dict-shaped adapter over this registry: ``schema`` maps each
        component-local key to its namespaced metric name; ``labels`` ride on
        every series the view touches (replica id, arch group, ...)."""
        return StatsView(self, schema, labels)


class StatsView(MutableMapping):
    """The old per-component ``stats`` dict, re-backed by the registry.

    Every key in ``schema`` exists from construction (value 0), exactly like
    ``_fresh_stats()`` used to guarantee — so ``for k in list(stats)`` resets
    and ``stats["x"] += 1`` bumps work unchanged, but each mutation lands in
    a namespaced, labelled registry series that exports/aggregates with the
    rest of the process's telemetry. Unknown keys raise: key drift between a
    component and its declared namespace is a bug, not a new metric.
    """

    __slots__ = ("_reg", "_schema", "_labels")

    def __init__(self, registry: MetricsRegistry, schema: Mapping[str, str],
                 labels: Mapping[str, object]):
        self._reg = registry
        self._schema = dict(schema)
        self._labels = dict(labels)

    @property
    def registry(self) -> MetricsRegistry:
        return self._reg

    @property
    def labels(self) -> Dict[str, object]:
        return dict(self._labels)

    def metric_name(self, key: str) -> str:
        return self._schema[key]

    def __getitem__(self, key: str) -> float:
        val = self._reg.value(self._schema[key], **self._labels)
        return int(val) if float(val).is_integer() else val

    def __setitem__(self, key: str, value: float) -> None:
        self._reg.set_counter(self._schema[key], value, **self._labels)

    def __delitem__(self, key: str) -> None:  # pragma: no cover - unused
        raise TypeError("StatsView keys are fixed by the component's schema")

    def __iter__(self) -> Iterator[str]:
        return iter(self._schema)

    def __len__(self) -> int:
        return len(self._schema)

    def __contains__(self, key: object) -> bool:
        return key in self._schema

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"StatsView({dict(self)!r}, labels={self._labels!r})"

"""Unified telemetry for the train + serve stacks.

Three layers, one import:

* **metrics registry** (:mod:`repro.obs.registry`) — counters / gauges /
  histograms under stable dotted namespaces with a labels dimension
  (replica id, arch group). Components hold a :class:`StatsView` over a
  registry instead of a free-floating stats dict; the names live ONCE in
  :mod:`repro.obs.names`.
* **span tracer** (:mod:`repro.obs.tracer`) — ``with obs.span("name"):``
  host-side nested spans into a ring buffer, exported as Perfetto-loadable
  Chrome trace-event JSON; bridges to ``jax.profiler.TraceAnnotation`` when
  a profiler trace is active.
* **per-request timelines** — ``Completion.first_token`` + the TTFT/queue-
  wait percentiles in :mod:`repro.serve.metrics`, dumped alongside the
  registry snapshot by the launchers' ``--metrics-out`` / ``--trace-out``.

Module-level state: ONE process-global registry and ONE process-global
tracer, both disabled until :func:`configure` (driven by the launcher
flags) switches them on — a disabled registry/tracer is an attribute check
per call site, so default runs pay nothing. Serving components additionally
create private always-on registries for their own stats (the replacement
for the dicts tests and log lines already read); the launcher hands them
the shared run registry instead so fleet series aggregate under replica
labels.
"""
from repro.obs.names import (
    KV_GAUGES,
    OFL_HISTOGRAMS,
    OFL_METRICS,
    REQUEST_HISTOGRAMS,
    REQUIRED_SERVE_KEYS,
    ROUTER_METRICS,
    SERVE_ENGINE_METRICS,
    serve_namespace,
)
from repro.obs.registry import MetricsRegistry, StatsView
from repro.obs.tracer import SpanTracer, start_jax_profile, stop_jax_profile

_registry = MetricsRegistry(enabled=False)
_tracer = SpanTracer()


def registry() -> MetricsRegistry:
    """The process-global registry (disabled until :func:`configure`)."""
    return _registry


def tracer() -> SpanTracer:
    """The process-global span tracer (disabled until :func:`configure`)."""
    return _tracer


def span(name: str, **args):
    """Open a span on the global tracer (no-op context when disabled)."""
    return _tracer.span(name, **args)


def instant(name: str, **args) -> None:
    """Zero-duration marker on the global tracer."""
    _tracer.instant(name, **args)


def observe(name: str, value: float, **labels) -> None:
    """Histogram observation on the global registry (no-op when disabled)."""
    _registry.observe(name, value, **labels)


def inc(name: str, value: float = 1, **labels) -> None:
    """Counter bump on the global registry (no-op when disabled)."""
    _registry.inc(name, value, **labels)


def configure(metrics: bool = False, trace: bool = False,
              profile_dir: str = None, trace_capacity: int = 65536) -> None:
    """Switch the process-global telemetry on/off (launcher flag plumbing).

    ``metrics`` enables the global registry, ``trace`` the span tracer (its
    ring is cleared so a run's export starts at t=0), and ``profile_dir``
    starts a JAX profiler trace bridging every span to a TraceAnnotation."""
    global _tracer
    _registry.enabled = metrics
    if trace and _tracer._events.maxlen != trace_capacity:
        _tracer = SpanTracer(capacity=trace_capacity)
    _tracer.enabled = trace
    if trace:
        _tracer.clear()
    if profile_dir:
        start_jax_profile(_tracer, profile_dir)


__all__ = [
    "MetricsRegistry",
    "StatsView",
    "SpanTracer",
    "KV_GAUGES",
    "OFL_HISTOGRAMS",
    "OFL_METRICS",
    "REQUEST_HISTOGRAMS",
    "REQUIRED_SERVE_KEYS",
    "ROUTER_METRICS",
    "SERVE_ENGINE_METRICS",
    "serve_namespace",
    "registry",
    "tracer",
    "span",
    "instant",
    "observe",
    "inc",
    "configure",
    "start_jax_profile",
    "stop_jax_profile",
]

"""Host-side span tracer: nested ``with obs.span("decode_chunk"): ...``
regions recorded into a bounded ring buffer and exported as Chrome
trace-event JSON (loadable in Perfetto / ``chrome://tracing``).

Contract with the serving hot path: a span brackets one HOST action (a
dispatch, a routing decision, an adoption scatter) — it never forces a
device sync, so the engine's O(1)-host-syncs-per-chunk invariant is
untouched whether tracing is on or off. When the tracer is disabled
(the default), :meth:`SpanTracer.span` returns a shared no-op context
manager: the cost of an instrumented call site is one attribute check.

Events use the Chrome trace-event "complete" phase (``ph: "X"``): each
record carries its own start timestamp and duration in microseconds plus
the recording thread id, so nesting is containment — Perfetto stacks spans
per thread without any explicit parent links. We additionally record the
enclosing span's name in ``args.parent`` (from a per-thread stack) so tests
and offline tooling can assert nesting without reconstructing intervals.

When a JAX profiler trace is active (``launch --profile-dir``), every span
also enters a :class:`jax.profiler.TraceAnnotation` of the same name, so
the host-side timeline lines up with the XLA device trace in one Perfetto
view.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Deque, List, Optional


class _NullSpan:
    """Shared no-op context manager — the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "args", "_t0", "_ann")

    def __init__(self, tracer: "SpanTracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0
        self._ann = None

    def __enter__(self):
        tr = self._tracer
        if tr.jax_bridge:
            import jax

            self._ann = jax.profiler.TraceAnnotation(self.name)
            self._ann.__enter__()
        stack = tr._stack()
        if stack:
            self.args.setdefault("parent", stack[-1])
        stack.append(self.name)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        tr._record(self.name, self._t0, t1, self.args)
        if self._ann is not None:
            self._ann.__exit__(*exc)
        return False


class SpanTracer:
    """Ring-buffered host span recorder with Chrome trace-event export."""

    def __init__(self, capacity: int = 65536):
        self.enabled = False
        self.jax_bridge = False  # set while a jax profiler trace is active
        self._events: Deque[dict] = deque(maxlen=capacity)
        self._local = threading.local()
        self._lock = threading.Lock()
        self._origin_ns = time.perf_counter_ns()

    # -- recording -----------------------------------------------------------

    def span(self, name: str, **args):
        """Open a span; disabled tracers hand back a shared no-op."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, name: str, t0_ns: int, t1_ns: int, args: dict) -> None:
        ev = {
            "name": name,
            "ph": "X",
            "ts": (t0_ns - self._origin_ns) / 1e3,  # microseconds
            "dur": (t1_ns - t0_ns) / 1e3,
            "pid": 0,
            "tid": threading.get_ident(),
        }
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker event (arrivals, evictions)."""
        if not self.enabled:
            return
        t = time.perf_counter_ns()
        self._record(name, t, t, args)

    # -- lifecycle -----------------------------------------------------------

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
        self._origin_ns = time.perf_counter_ns()

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    # -- export --------------------------------------------------------------

    def events(self) -> List[dict]:
        """Events sorted by start time (ties: longest span first, so a parent
        precedes the children it contains). The ring records at span EXIT —
        children land before their parents — so raw buffer order is not
        start-ordered; the export re-sorts, which also makes per-thread ``ts``
        monotonic for the validator."""
        with self._lock:
            evs = list(self._events)
        return sorted(evs, key=lambda e: (e["ts"], -e.get("dur", 0.0)))

    def to_chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object Perfetto loads directly."""
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"recorder": "repro.obs.tracer"},
        }

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


# -- jax profiler bridge -----------------------------------------------------


def start_jax_profile(tracer: SpanTracer, profile_dir: str) -> bool:
    """Start a JAX profiler trace into ``profile_dir`` and bridge every span
    to a TraceAnnotation so host spans land in the device timeline too.
    Returns False (and leaves the tracer untouched) when the installed jax
    has no profiler support."""
    try:
        import jax

        jax.profiler.start_trace(profile_dir)
    except Exception:  # pragma: no cover - depends on jax build
        return False
    tracer.jax_bridge = True
    return True


def stop_jax_profile(tracer: SpanTracer) -> None:
    tracer.jax_bridge = False
    try:
        import jax

        jax.profiler.stop_trace()
    except Exception:  # pragma: no cover - stop without start, old jax
        pass

"""Telemetry artifact validator — the CI lanes' cheap gate.

    PYTHONPATH=src python -m repro.obs.validate \
        --metrics results/serve_metrics.jsonl --trace results/serve_trace.json

Fails (exit 1) when:

* the trace file is not parseable Chrome trace-event JSON, has no
  ``traceEvents``, or any event lacks ``name``/``ts`` (or, for complete
  events, ``dur``);
* per thread, complete-event start timestamps are not monotonically
  non-decreasing (a scrambled ring buffer / clock bug);
* the metrics JSONL snapshot is unreadable or is missing any of the
  required serve-namespace keys (:data:`repro.obs.names.REQUIRED_SERVE_KEYS`)
  — the drift guard that keeps a component rename from silently emptying
  the dashboards.

``--train`` switches the required-key set to the ofl namespace.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

from repro.obs.names import REQUIRED_SERVE_KEYS

REQUIRED_OFL_KEYS = ("ofl.epoch.count", "ofl.epoch.step_s")


def validate_trace(path: str) -> list:
    """Returns the parsed events; raises ValueError on malformed traces."""
    with open(path) as f:
        doc = json.load(f)  # json.loads round-trip IS the parseability check
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise ValueError(f"{path}: traceEvents is not a list")
    last_ts = defaultdict(lambda: float("-inf"))
    for ev in events:
        if "name" not in ev or "ts" not in ev:
            raise ValueError(f"{path}: event missing name/ts: {ev!r}")
        if ev.get("ph", "X") == "X" and "dur" not in ev:
            raise ValueError(f"{path}: complete event missing dur: {ev!r}")
        tid = ev.get("tid", 0)
        if ev["ts"] < last_ts[tid]:
            raise ValueError(
                f"{path}: non-monotonic ts on tid {tid}: {ev['ts']} after {last_ts[tid]}"
            )
        last_ts[tid] = ev["ts"]
    return events


def validate_metrics(path: str, required=REQUIRED_SERVE_KEYS) -> list:
    """Returns the parsed records; raises ValueError on missing keys."""
    records = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as ex:
                raise ValueError(f"{path}:{i + 1}: unparseable JSONL line: {ex}")
    names = {r.get("name") for r in records}
    missing = [k for k in required if k not in names]
    if missing:
        raise ValueError(
            f"{path}: metrics snapshot is missing required keys {missing} "
            f"(has {len(names)} names) — component/namespace drift?"
        )
    return records


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--metrics", default=None, help="metrics JSONL snapshot")
    p.add_argument("--trace", default=None, help="Chrome trace-event JSON")
    p.add_argument("--train", action="store_true",
                   help="require the ofl.* namespace instead of serve.*")
    args = p.parse_args(argv)
    if not args.metrics and not args.trace:
        p.error("nothing to validate: pass --metrics and/or --trace")
    try:
        if args.trace:
            events = validate_trace(args.trace)
            print(f"ok: {args.trace} ({len(events)} events)")
        if args.metrics:
            required = REQUIRED_OFL_KEYS if args.train else REQUIRED_SERVE_KEYS
            records = validate_metrics(args.metrics, required)
            print(f"ok: {args.metrics} ({len(records)} series)")
    except (OSError, ValueError, KeyError, json.JSONDecodeError) as ex:
        print(f"telemetry validation FAILED: {ex}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

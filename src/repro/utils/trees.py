"""Pytree utilities used across the framework.

Params everywhere in repro are nested ``dict``s of ``jnp.ndarray`` leaves.
Paths are "/"-joined key strings (e.g. ``"block/attn/wq"``); the sharding
rules in :mod:`repro.sharding` match on these paths.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(path_str, leaf)`` over a pytree."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_path_str(p), x), tree)


def tree_paths(tree: Any) -> List[str]:
    """Return the "/"-joined path of every leaf."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [_path_str(p) for p, _ in flat]


def tree_size(tree: Any) -> int:
    """Total number of scalar elements in the tree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes (uses leaf dtypes; works on ShapeDtypeStruct too)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_stack(trees: List[Any]) -> Any:
    """Stack a list of identically-structured trees along a new axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: Any, n: int) -> List[Any]:
    """Inverse of :func:`tree_stack`."""
    return [jax.tree_util.tree_map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_index(tree: Any, i) -> Any:
    """Index every leaf's axis 0 (traceable; ``i`` may be a tracer)."""
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree: Any, s) -> Any:
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_l2_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def flatten_dict(d: Dict[str, Any], prefix: str = "") -> Dict[str, Any]:
    """Flatten a nested dict into {"a/b/c": leaf}."""
    out: Dict[str, Any] = {}
    for k, v in d.items():
        key = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten_dict(v, key))
        else:
            out[key] = v
    return out


def unflatten_dict(d: Dict[str, Any]) -> Dict[str, Any]:
    """Inverse of :func:`flatten_dict`."""
    out: Dict[str, Any] = {}
    for k, v in d.items():
        parts = k.split("/")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out


def iter_leaves_with_path(tree: Any) -> Iterator[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for p, x in flat:
        yield _path_str(p), x

"""Thin stdlib logging wrapper with a consistent format.

The ``repro`` root level comes from the ``REPRO_LOG_LEVEL`` environment
variable (``DEBUG``/``INFO``/``WARNING``/... or a numeric level; default
``INFO``) so a noisy run can be quieted — or a quiet one opened up — without
touching code; :func:`set_level` changes it at runtime.
"""
from __future__ import annotations

import logging
import os
import sys

_FORMAT = "%(asctime)s %(name)s %(levelname).1s | %(message)s"
_configured = False


def _level_from_env(default: int = logging.INFO) -> int:
    raw = os.environ.get("REPRO_LOG_LEVEL", "").strip()
    if not raw:
        return default
    if raw.isdigit():
        return int(raw)
    level = logging.getLevelName(raw.upper())
    return level if isinstance(level, int) else default


def set_level(level) -> None:
    """Set the ``repro`` root logger level: a logging constant, a numeric
    value, or a name like ``"debug"``."""
    if isinstance(level, str):
        resolved = logging.getLevelName(level.upper())
        if not isinstance(resolved, int):
            raise ValueError(f"unknown log level {level!r}")
        level = resolved
    logging.getLogger("repro").setLevel(level)


def get_logger(name: str) -> logging.Logger:
    global _configured
    if not _configured:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
        root = logging.getLogger("repro")
        root.addHandler(handler)
        root.setLevel(_level_from_env())
        root.propagate = False
        _configured = True
    return logging.getLogger(f"repro.{name}")

"""Deterministic PRNG stream helper.

Every stochastic component in the framework draws from a named stream so
runs are reproducible and independent components never share keys.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


class PRNGStream:
    """Named, counted PRNG key factory.

    >>> rng = PRNGStream(0)
    >>> k1 = rng("generator")   # distinct from
    >>> k2 = rng("generator")   # this one, and from
    >>> k3 = rng("server")      # this one.
    """

    def __init__(self, seed: int):
        self._base = jax.random.key(seed)
        self._counts: dict = {}

    def __call__(self, name: str) -> jax.Array:
        count = self._counts.get(name, 0)
        self._counts[name] = count + 1
        return jax.random.fold_in(
            jax.random.fold_in(self._base, _stable_hash(name)), count
        )

    def fork(self, name: str) -> "PRNGStream":
        child = PRNGStream.__new__(PRNGStream)
        child._base = self(name)
        child._counts = {}
        return child


def _stable_hash(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = ((h ^ ch) * 16777619) & 0x7FFFFFFF
    return h


def split_like(key: jax.Array, tree: Any) -> Any:
    """Split ``key`` into one key per leaf of ``tree`` (same structure)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))

"""Small shared utilities: pytree helpers, PRNG streams, logging."""
from repro.utils.trees import (
    tree_map_with_path,
    tree_paths,
    tree_size,
    tree_bytes,
    tree_stack,
    tree_unstack,
    tree_index,
    tree_zeros_like,
    tree_cast,
    tree_add,
    tree_scale,
    tree_l2_norm,
    flatten_dict,
    unflatten_dict,
)
from repro.utils.prng import PRNGStream, split_like
from repro.utils.logging import get_logger, set_level

__all__ = [
    "tree_map_with_path",
    "tree_paths",
    "tree_size",
    "tree_bytes",
    "tree_stack",
    "tree_unstack",
    "tree_index",
    "tree_zeros_like",
    "tree_cast",
    "tree_add",
    "tree_scale",
    "tree_l2_norm",
    "flatten_dict",
    "unflatten_dict",
    "PRNGStream",
    "split_like",
    "get_logger",
    "set_level",
]

"""Chunk-checkpointed scan for recurrent mixers.

BPTT through ``lax.scan`` saves the carry at *every* step — for mLSTM the
carry is the (B, H, hd, hd) matrix memory, i.e. O(T · B · d²) residuals for
a T-step sequence (38 GB/device at 4k tokens). ``chunked_scan`` nests two
scans: an outer scan over chunks whose body is ``jax.checkpoint``-ed, so
only chunk-boundary carries are saved and the within-chunk states are
recomputed during the backward pass. Memory: O(T/chunk · |carry| +
chunk · |step residuals|).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def chunked_scan(cell: Callable, carry: Any, xs: Any, chunk: int, use_checkpoint: bool = True):
    """Like ``jax.lax.scan(cell, carry, xs)`` but checkpointed at chunk
    boundaries. xs leaves have leading time axis T; falls back to a plain
    scan when T is not divisible by ``chunk``."""
    leaves = jax.tree_util.tree_leaves(xs)
    t = leaves[0].shape[0]
    chunk = min(chunk, t)
    if t % chunk or chunk == t:
        return jax.lax.scan(cell, carry, xs)
    n = t // chunk

    def chunk_body(c, xs_chunk):
        return jax.lax.scan(cell, c, xs_chunk)

    if use_checkpoint:
        chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)

    reshape = lambda x: x.reshape(n, chunk, *x.shape[1:])
    carry, ys = jax.lax.scan(chunk_body, carry, jax.tree_util.tree_map(reshape, xs))
    unshape = lambda y: y.reshape(n * chunk, *y.shape[2:])
    return carry, jax.tree_util.tree_map(unshape, ys)

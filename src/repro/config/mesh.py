"""Mesh configuration: logical axes and hardware constants (TPU v5e)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        """Axes batch/FSDP shard over (pod folds into data parallel)."""
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"

    def axis_size(self, name: str) -> int:
        return self.shape[self.axes.index(name)]


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))

# TPU v5e hardware constants used by the roofline model.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per the assignment sheet)
HBM_BYTES = 16 * 1024**3
VMEM_BYTES = 128 * 1024 * 1024

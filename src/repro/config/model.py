"""Frozen model configuration covering every assigned architecture family.

One dataclass describes dense, MoE, SSM (mamba / xlstm), hybrid, encoder-only
(audio) and VLM decoders. Family-specific fields default to "off". Every
config file in :mod:`repro.configs` instantiates exactly one of these and
registers it.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.kernels.dispatch import BackendPolicy


@dataclass(frozen=True)
class ModelConfig:
    # identity ---------------------------------------------------------------
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""  # citation for the assigned config

    # trunk ------------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"  # silu (SwiGLU) | gelu (plain MLP, hubert)

    # attention --------------------------------------------------------------
    rope_theta: float = 10000.0
    qk_norm: bool = False
    causal: bool = True
    sliding_window: int = 0  # 0 = full attention
    attn_logit_softcap: float = 0.0

    # MoE --------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # expert hidden dim; 0 => d_ff
    router_aux_coef: float = 0.01
    shared_expert: bool = False
    moe_impl: str = "einsum"  # einsum (GShard dispatch, baseline) | scatter (dropless-ish)
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 0  # tokens per dispatch group; 0 => one group per sequence

    # SSM / hybrid -----------------------------------------------------------
    ssm_kind: str = ""  # "" | mamba | xlstm
    ssm_state_dim: int = 16  # mamba N
    ssm_conv_dim: int = 4  # mamba depthwise conv width
    ssm_expand: int = 2  # mamba inner expansion
    ssm_chunk: int = 128  # selective-scan chunk length (intra-chunk parallel)
    dt_rank: int = 0  # mamba dt low-rank; 0 => ceil(d_model / 16)
    attn_every: int = 0  # hybrid: one attention layer per this many (jamba=8)
    moe_every: int = 0  # hybrid: MoE MLP every this many layers (jamba=2)
    slstm_every: int = 0  # xlstm: one sLSTM block per this many (rest mLSTM)
    xlstm_heads: int = 4

    # modality frontend stub ---------------------------------------------------
    frontend: str = ""  # "" | vision | audio
    frontend_dim: int = 0  # raw patch/frame embedding dim fed to the projector
    num_prefix_tokens: int = 0  # patch/frame embeddings provided by input_specs

    # unified backend policy for the dispatched ops this model touches
    # ("attn": train/prefill flash attention; "decode": paged Sq=1 decode —
    # dense caches always use the small SDPA path). See
    # repro.kernels.dispatch.BackendPolicy; resolved via backend_for(op).
    backend: Optional[BackendPolicy] = None
    # DEPRECATED aliases (the pre-policy knobs). Still honored when no
    # `backend` policy is set; an explicit policy wins over both.
    attn_backend: str = "auto"
    decode_backend: str = "auto"

    # numerics -----------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    logit_dtype: str = "float32"

    # derived -------------------------------------------------------------------
    def backend_for(self, op: str) -> str:
        """The requested backend for ``op`` under the policy/alias
        precedence: an explicit :class:`BackendPolicy` wins; otherwise the
        deprecated ``attn_backend`` / ``decode_backend`` aliases apply."""
        if self.backend is not None:
            return self.backend.for_op(op)
        if op == "attn":
            return self.attn_backend
        if op == "decode":
            return self.decode_backend
        return "auto"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def is_encoder_only(self) -> bool:
        return self.family == "audio"

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can decode at 500k+ context (SSM/hybrid state or SWA)."""
        return self.ssm_kind != "" or self.sliding_window > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, -(-self.d_model // 16))

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def validate(self) -> None:
        assert self.family in ("dense", "moe", "ssm", "hybrid", "audio", "vlm"), self.family
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: heads {self.num_heads} not divisible by kv {self.num_kv_heads}"
        )
        if self.family == "moe":
            assert self.num_experts > 0 and self.experts_per_token > 0
        if self.family == "hybrid":
            assert self.ssm_kind and self.attn_every > 0
        if self.family == "ssm":
            assert self.ssm_kind in ("mamba", "xlstm")
        if self.frontend:
            assert self.num_prefix_tokens > 0
        assert self.attn_backend in ("auto", "pallas", "pallas-interpret", "ref"), self.attn_backend
        assert self.decode_backend in ("auto", "pallas", "pallas-interpret", "ref"), self.decode_backend

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter count estimate (for roofline MODEL_FLOPS = 6 N D) --------------
    def param_count(self, active_only: bool = False) -> int:
        d, h = self.d_model, self.head_dim_
        attn = d * h * self.num_heads + 2 * d * h * self.num_kv_heads + self.num_heads * h * d
        if self.act == "silu":
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        n_layers = self.num_layers
        per_layer = 0
        for i in range(n_layers):
            is_attn = True
            if self.family in ("ssm",) or (
                self.family == "hybrid" and self.attn_every and (i % self.attn_every) != (self.attn_every - 1)
            ):
                is_attn = self.family != "ssm" and False
            layer = 0
            if self.family == "ssm" and self.ssm_kind == "mamba":
                inner = self.ssm_expand * d
                layer += 2 * d * inner + inner * self.ssm_conv_dim
                layer += inner * (2 * self.ssm_state_dim + 1) + inner * d
            elif self.family == "ssm" and self.ssm_kind == "xlstm":
                inner = self.ssm_expand * d
                layer += 2 * d * inner + 4 * inner * inner // max(self.xlstm_heads, 1) + inner * d
            elif self.family == "hybrid" and not is_attn:
                inner = self.ssm_expand * d
                layer += 2 * d * inner + inner * self.ssm_conv_dim
                layer += inner * (2 * self.ssm_state_dim + 1) + inner * d
            else:
                layer += attn
            # MLP
            use_moe = self.num_experts > 0 and (
                self.family == "moe"
                or (self.family == "hybrid" and self.moe_every and i % self.moe_every == self.moe_every - 1)
            )
            if use_moe:
                e = self.num_experts if not active_only else self.experts_per_token
                layer += e * 3 * d * self.expert_d_ff + d * self.num_experts
            elif self.family not in ("ssm",):
                layer += mlp_dense
            layer += 2 * d  # norms
            per_layer += layer
        embed = self.vocab_size * d
        head = 0 if self.tie_embeddings or self.is_encoder_only else self.vocab_size * d
        if self.is_encoder_only:
            head = self.vocab_size * d  # frame-codebook prediction head
        return per_layer + embed + head + d


def reduced_variant(cfg: ModelConfig) -> ModelConfig:
    """The CPU-smoke-test variant: <=2 layers (or one full interleave group for
    hybrids), d_model<=512, <=4 experts — same family and code paths."""
    d_model = min(cfg.d_model, 128)
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    layers = 2
    kw = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32,
        d_ff=min(cfg.d_ff, 256) or 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        num_prefix_tokens=min(cfg.num_prefix_tokens, 16) if cfg.num_prefix_tokens else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        ssm_chunk=16,
        moe_group_size=0,
        scan_layers=False,
        remat=False,
        name=cfg.name + "-smoke",
    )
    if cfg.family == "hybrid":
        kw["num_layers"] = cfg.attn_every  # one full interleave group
        kw["attn_every"] = cfg.attn_every
        kw["moe_every"] = cfg.moe_every
    if cfg.family == "ssm" and cfg.ssm_kind == "xlstm" and cfg.slstm_every:
        kw["num_layers"] = max(2, cfg.slstm_every)
        kw["xlstm_heads"] = min(cfg.xlstm_heads, 4)
    return cfg.replace(**kw)

"""Training / OFL run configuration dataclasses."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.kernels.dispatch import BackendPolicy


@dataclass(frozen=True)
class TrainConfig:
    """Generic trainer knobs (client local training and server distillation
    both reuse this)."""

    optimizer: str = "sgdm"  # sgd | sgdm | adam | adamw
    learning_rate: float = 0.01
    momentum: float = 0.9
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    grad_clip_norm: float = 0.0
    schedule: str = "constant"  # constant | cosine | linear_warmup_cosine
    warmup_steps: int = 0
    total_steps: int = 1000
    batch_size: int = 128
    seed: int = 0
    microbatches: int = 1  # grad accumulation inside a train step
    state_dtype: str = ""  # optimizer slot dtype override (e.g. "bfloat16")
    grad_dtype: str = ""  # cast grads before the optimizer (e.g. "bfloat16")


@dataclass(frozen=True)
class OFLConfig:
    """One-shot federated learning pipeline configuration (the paper's
    hyperparameters from Appendix B.1, scaled for this container by the
    benchmark/test drivers)."""

    num_clients: int = 10
    partition: str = "dirichlet"  # dirichlet | c_cls | iid
    alpha: float = 0.1  # Dir(alpha)
    c_cls: int = 2  # classes per client under c_cls partition
    lognormal_sigma: float = 0.0  # >0 => unbalanced client sizes

    # local client training
    local_epochs: int = 300
    local_lr: float = 0.01
    local_momentum: float = 0.9
    local_batch_size: int = 128

    # Co-Boosting (Algorithm 1)
    epochs: int = 500  # T, global epochs
    gen_iters: int = 30  # T_G
    gen_lr: float = 1e-3  # eta_G (Adam)
    server_lr: float = 0.01  # eta_S (SGD momentum 0.9)
    batch_size: int = 128  # b, synthetic batch per epoch
    latent_dim: int = 100
    kd_temperature: float = 4.0  # server distillation temperature
    gen_kl_temperature: float = 1.0  # temperature in the generator's KL term
    beta: float = 1.0  # scale on the adversarial generator loss (Eq. 8)
    epsilon: float = 8.0 / 255.0  # DHS perturbation strength (Eq. 10)
    mu: float = 0.1  # EE step size, divided by n (Appendix: 0.1/n)
    buffer_batches: int = 8  # replay window over D_S (memory bound on CPU)

    # component toggles (Table 7 ablation)
    use_ghs: bool = True  # hard-sample generator loss (Eq. 6)
    use_dhs: bool = True  # on-the-fly diverse hard samples (Eq. 10)
    use_ee: bool = True  # ensemble enhancement (Eq. 12)
    use_adv: bool = True  # adversarial term (Eq. 7); part of GHS in ablations

    # client ensemble forward engine: "grouped" (ClientBank — clients grouped
    # by arch, one vmapped forward per group, O(#groups) trace cost) or
    # "looped" (the original K-way python-unrolled loop, kept as the parity
    # baseline). The legacy driver always loops.
    ensemble_impl: str = "grouped"
    # >0: cap concurrent client forwards inside a grouped vmap — a group
    # larger than this is evaluated as a lax.scan over vmapped chunks of
    # this size (bounds live (chunk, B, C) activations at hundreds of
    # clients; 0 = one vmap per group)
    ensemble_scan_chunk: int = 0

    # unified backend policy for every dispatched op (loss/attn/decode) —
    # see repro.kernels.dispatch.BackendPolicy. When None, the deprecated
    # kernel_backend alias below feeds the "loss" op.
    backend: Optional[BackendPolicy] = None
    # DEPRECATED alias (the pre-policy knob): fused-loss kernel backend for
    # the Eq. 4/Eq. 6 hot path. Forwarded into backend_for("loss") when no
    # policy is set; an explicit `backend` policy wins over it.
    kernel_backend: str = "auto"

    seed: int = 0

    def backend_for(self, op: str) -> str:
        """The requested backend for ``op`` under the policy/alias
        precedence: an explicit :class:`BackendPolicy` wins; otherwise the
        deprecated ``kernel_backend`` alias covers the "loss" op."""
        if self.backend is not None:
            return self.backend.for_op(op)
        return self.kernel_backend if op == "loss" else "auto"

"""Architecture registry.

Configs register themselves at import; ``get_arch`` lazily imports
``repro.configs`` so the registry is populated on first use. Arch ids use
dashes (CLI form); module names use underscores.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config.model import ModelConfig
from repro.config.shapes import ShapeConfig

_REGISTRY: Dict[str, ModelConfig] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    cfg.validate()
    _REGISTRY[cfg.name] = cfg
    return cfg


def _ensure_loaded() -> None:
    if not _REGISTRY:
        importlib.import_module("repro.configs")


def get_arch(name: str) -> ModelConfig:
    _ensure_loaded()
    key = name.replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def arch_supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """Return "" if supported, else a human-readable skip reason.

    Skip rules (documented in DESIGN.md):
      * encoder-only archs have no decode step;
      * long_500k decode requires a sub-quadratic path (SSM state or SWA).
    """
    if cfg.is_encoder_only and shape.kind == "decode":
        return "encoder-only: no autoregressive decode step"
    if shape.kind == "decode" and shape.seq_len > 100_000 and not cfg.is_subquadratic:
        return "long_500k needs sub-quadratic attention (no SWA/SSM path)"
    return ""

from repro.config.model import ModelConfig, reduced_variant
from repro.config.shapes import ShapeConfig, INPUT_SHAPES
from repro.config.mesh import MeshConfig
from repro.config.train import TrainConfig, OFLConfig
from repro.config.registry import (
    register_arch,
    get_arch,
    list_archs,
    arch_supports_shape,
)

__all__ = [
    "ModelConfig",
    "reduced_variant",
    "ShapeConfig",
    "INPUT_SHAPES",
    "MeshConfig",
    "TrainConfig",
    "OFLConfig",
    "register_arch",
    "get_arch",
    "list_archs",
    "arch_supports_shape",
]

from repro.checkpoint.npz import save_checkpoint, load_checkpoint, list_checkpoints

__all__ = ["save_checkpoint", "load_checkpoint", "list_checkpoints"]

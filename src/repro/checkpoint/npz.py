"""Flat-npz pytree checkpointing with an index manifest.

Params are nested dicts; we flatten to "a/b/c" keys, store one ``.npz`` per
step plus a ``manifest.json`` recording steps, shapes and metadata. Arrays
are pulled to host (fully addressable values only — on a real multi-host
mesh you would gather or save per-shard; this container is single-host).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.utils import flatten_dict, unflatten_dict


def _manifest_path(ckpt_dir: str) -> str:
    return os.path.join(ckpt_dir, "manifest.json")


def _read_manifest(ckpt_dir: str) -> Dict:
    path = _manifest_path(ckpt_dir)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"steps": [], "meta": {}}


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, meta: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = flatten_dict(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    fname = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    np.savez(fname, **host)
    manifest = _read_manifest(ckpt_dir)
    if step not in manifest["steps"]:
        manifest["steps"].append(step)
        manifest["steps"].sort()
    manifest["meta"][str(step)] = dict(meta or {}, keys=len(host))
    with open(_manifest_path(ckpt_dir), "w") as f:
        json.dump(manifest, f, indent=1)
    return fname


def load_checkpoint(ckpt_dir: str, step: Optional[int] = None) -> Any:
    manifest = _read_manifest(ckpt_dir)
    if not manifest["steps"]:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    step = manifest["steps"][-1] if step is None else step
    fname = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(fname) as data:
        flat = {k: data[k] for k in data.files}
    return unflatten_dict(flat)


def list_checkpoints(ckpt_dir: str) -> List[int]:
    return list(_read_manifest(ckpt_dir)["steps"])

"""Learning-rate schedules."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.0) -> Schedule:
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)

    return fn


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1))

    def fn(step):
        warm = lr * (step + 1) / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return fn


def make_schedule(cfg) -> Schedule:
    if cfg.schedule == "constant":
        return constant_schedule(cfg.learning_rate)
    if cfg.schedule == "cosine":
        return cosine_schedule(cfg.learning_rate, cfg.total_steps)
    if cfg.schedule == "linear_warmup_cosine":
        return linear_warmup_cosine(cfg.learning_rate, cfg.warmup_steps, cfg.total_steps)
    raise ValueError(f"unknown schedule {cfg.schedule!r}")

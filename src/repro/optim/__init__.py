from repro.optim.optimizers import (
    Optimizer,
    sgd,
    sgdm,
    adam,
    adamw,
    make_optimizer,
    clip_by_global_norm,
)
from repro.optim.schedules import (
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
    make_schedule,
)

__all__ = [
    "Optimizer",
    "sgd",
    "sgdm",
    "adam",
    "adamw",
    "make_optimizer",
    "clip_by_global_norm",
    "constant_schedule",
    "cosine_schedule",
    "linear_warmup_cosine",
    "make_schedule",
]

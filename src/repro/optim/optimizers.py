"""Minimal optax-style optimizers, built in-house per the substrate mandate.

An :class:`Optimizer` is an ``(init, update)`` pair over pytrees:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

All states are pytrees with the same structure (and hence the same
PartitionSpecs) as the parameters, so FSDP sharding of optimizer state comes
for free from :func:`repro.sharding.infer_param_specs`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config.train import TrainConfig
from repro.optim.schedules import make_schedule

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Any]  # grads, state, params, step


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads: Any, max_norm: float) -> Any:
    if max_norm <= 0:
        return grads
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        del params
        u = jax.tree_util.tree_map(lambda g: -lr(step) * g, grads)
        return u, state

    return Optimizer(init, update)


def sgdm(
    lr: Schedule,
    momentum: float = 0.9,
    weight_decay: float = 0.0,
    state_dtype: Optional[str] = None,
) -> Optimizer:
    """SGD with (heavy-ball) momentum — the paper's client/server optimizer.

    ``state_dtype`` (e.g. "bfloat16") stores the momentum slot at reduced
    precision — a §Perf memory lever for the 235B-param dry-runs; the
    accumulation itself happens in f32."""

    def init(params):
        def z(p):
            dt = jnp.dtype(state_dtype) if state_dtype else p.dtype
            return jnp.zeros(p.shape, dt)

        return {"m": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        if weight_decay:
            grads = jax.tree_util.tree_map(lambda g, p: g + weight_decay * p, grads, params)
        m = jax.tree_util.tree_map(
            lambda m_, g: (momentum * m_.astype(jnp.float32) + g.astype(jnp.float32)).astype(m_.dtype),
            state["m"],
            grads,
        )
        u = jax.tree_util.tree_map(lambda m_: -lr(step) * m_.astype(jnp.float32), m)
        return u, {"m": m}

    return Optimizer(init, update)


def _adam_core(lr, b1, b2, eps, weight_decay, decoupled):
    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, z)}

    def update(grads, state, params, step):
        step = step.astype(jnp.float32) + 1.0
        if weight_decay and not decoupled:
            grads = jax.tree_util.tree_map(lambda g, p: g + weight_decay * p, grads, params)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )
        bc1 = 1 - b1**step
        bc2 = 1 - b2**step
        def u_fn(m_, v_, p):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and decoupled:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (-lr(step - 1.0) * upd).astype(p.dtype)

        u = jax.tree_util.tree_map(u_fn, m, v, params)
        return u, {"m": m, "v": v}

    return Optimizer(init, update)


def adam(lr: Schedule, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay, decoupled=False)


def adamw(lr: Schedule, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay, decoupled=True)


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    lr = make_schedule(cfg)
    if cfg.optimizer == "sgd":
        return sgd(lr)
    if cfg.optimizer == "sgdm":
        return sgdm(lr, cfg.momentum, cfg.weight_decay, state_dtype=cfg.state_dtype or None)
    if cfg.optimizer == "adam":
        return adam(lr, cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay)
    if cfg.optimizer == "adamw":
        return adamw(lr, cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")

"""Continuous-batching serving subsystem for the distilled server LM.

* :mod:`repro.serve.engine`    — slot-based device engine: bucketed prefill
  admission, ``lax.while_loop`` decode chunks with on-device sampling (O(1)
  host syncs per chunk), per-slot positions.
* :mod:`repro.serve.kv_pool`   — paged KV memory: fixed-size page pool +
  free list + per-slot page tables (the default ``kv_layout="paged"``; HBM
  scales with live tokens, decode attention runs the flash-decode kernel).
* :mod:`repro.serve.scheduler` — request queue, admission into free slots,
  eviction/drain of finished sequences, arrival clock.
* :mod:`repro.serve.static`    — the static-batch baseline arm, fused into
  a single dispatch (no per-token host sync; always the dense cache — the
  cross-layout parity oracle).

A/B: ``python -m benchmarks.perf_hillclimb --pair servepath`` (continuous vs
static) and ``--pair decodepath`` (paged-flash vs dense-SDPA decode).
"""
from repro.serve.engine import DecodeState, EngineConfig, ServeEngine, sample_tokens
from repro.serve.kv_pool import KVPool
from repro.serve.scheduler import (
    Completion,
    ContinuousScheduler,
    ManualClock,
    MonotonicClock,
    Request,
)
from repro.serve.static import make_static_generator, static_generate

__all__ = [
    "DecodeState",
    "EngineConfig",
    "KVPool",
    "ServeEngine",
    "sample_tokens",
    "Completion",
    "ContinuousScheduler",
    "ManualClock",
    "MonotonicClock",
    "Request",
    "make_static_generator",
    "static_generate",
]

"""Continuous-batching serving subsystem for the distilled server LM.

* :mod:`repro.serve.engine`    — slot-based device engine: batched KV cache
  with per-slot lengths, bucketed prefill admission, ``lax.while_loop``
  decode chunks with on-device sampling (O(1) host syncs per chunk).
* :mod:`repro.serve.scheduler` — request queue, admission into free slots,
  eviction/drain of finished sequences, arrival clock.
* :mod:`repro.serve.static`    — the static-batch baseline arm, fused into
  a single dispatch (no per-token host sync).

A/B: ``python -m benchmarks.perf_hillclimb --pair servepath``.
"""
from repro.serve.engine import DecodeState, EngineConfig, ServeEngine, sample_tokens
from repro.serve.scheduler import (
    Completion,
    ContinuousScheduler,
    ManualClock,
    MonotonicClock,
    Request,
)
from repro.serve.static import make_static_generator, static_generate

__all__ = [
    "DecodeState",
    "EngineConfig",
    "ServeEngine",
    "sample_tokens",
    "Completion",
    "ContinuousScheduler",
    "ManualClock",
    "MonotonicClock",
    "Request",
    "make_static_generator",
    "static_generate",
]

"""Continuous-batching serving subsystem for the distilled server LM.

* :mod:`repro.serve.engine`    — the worker pair: :class:`PrefillWorker`
  (bucketed prefill admission sealed into :class:`KVHandoff`\\ s) and
  :class:`DecodeWorker` (slot-based ``lax.while_loop`` decode chunks with
  on-device sampling, O(1) host syncs per chunk, per-slot positions), with
  :class:`ServeEngine` as their colocated composition — one fleet replica.
* :mod:`repro.serve.kv_pool`   — paged KV memory: fixed-size page pool +
  free list + per-slot page tables + per-page refcounts (the default
  ``kv_layout="paged"``; HBM scales with live tokens, decode attention runs
  the flash-decode kernel), plus the ``donate``/``adopt`` handoff protocol
  between worker pools and the ``attach``/``cow`` sharing transitions.
* :mod:`repro.serve.prefix_cache` — radix trie over resident page runs:
  hot admissions splice matched pages into a fresh slot's table and prefill
  only the uncovered tail; LRU eviction only ever frees orphaned pages.
* :mod:`repro.serve.spec_decode` — ensemble-drafter speculative decoding:
  a small registry model drafts k tokens, the target verifies them in one
  batched extend — greedy token parity with plain decode is the contract.
* :mod:`repro.serve.scheduler` — :class:`FleetRouter`: request queue +
  prefix-affinity/least-loaded admission across N replicas,
  requeue-on-defer, per-replica eviction/drain, arrival clock;
  ``ContinuousScheduler`` is the N=1 case.
* :mod:`repro.serve.static`    — the static-batch baseline arm, fused into
  a single dispatch (no per-token host sync; always the dense cache — the
  cross-layout parity oracle).
* :mod:`repro.serve.traffic` / :mod:`repro.serve.metrics` — shared seeded
  request streams and latency/queue-wait percentile summaries, used by the
  launcher, the perf pairs and the scheduler property tests alike.

A/B: ``python -m benchmarks.perf_hillclimb --pair servepath`` (continuous vs
static), ``--pair decodepath`` (paged-flash vs dense-SDPA decode),
``--pair fleetpath`` (routed disaggregated fleet vs monolithic engine) and
``--pair specpath`` (prefix cache + speculative decoding vs plain engine on
hot-prefix traffic).
"""
from repro.serve.engine import (
    DecodeState,
    DecodeWorker,
    EngineConfig,
    KVHandoff,
    PrefillWorker,
    ServeEngine,
    sample_tokens,
)
from repro.serve.kv_pool import KVPool
from repro.serve.metrics import latency_summary, percentile
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import (
    Completion,
    ContinuousScheduler,
    FleetRouter,
    ManualClock,
    MonotonicClock,
    Request,
)
from repro.serve.spec_decode import SpecDecoder
from repro.serve.static import make_static_generator, static_generate
from repro.serve.traffic import (
    hot_prefix_stream,
    ragged_stream,
    staggered_stream,
    with_arrivals,
)

__all__ = [
    "DecodeState",
    "DecodeWorker",
    "EngineConfig",
    "KVHandoff",
    "KVPool",
    "PrefillWorker",
    "PrefixCache",
    "ServeEngine",
    "SpecDecoder",
    "sample_tokens",
    "Completion",
    "ContinuousScheduler",
    "FleetRouter",
    "ManualClock",
    "MonotonicClock",
    "Request",
    "latency_summary",
    "percentile",
    "hot_prefix_stream",
    "ragged_stream",
    "staggered_stream",
    "with_arrivals",
    "make_static_generator",
    "static_generate",
]

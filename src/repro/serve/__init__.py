"""Continuous-batching serving subsystem for the distilled server LM.

* :mod:`repro.serve.engine`    — the worker pair: :class:`PrefillWorker`
  (bucketed prefill admission sealed into :class:`KVHandoff`\\ s) and
  :class:`DecodeWorker` (slot-based ``lax.while_loop`` decode chunks with
  on-device sampling, O(1) host syncs per chunk, per-slot positions), with
  :class:`ServeEngine` as their colocated composition — one fleet replica.
* :mod:`repro.serve.kv_pool`   — paged KV memory: fixed-size page pool +
  free list + per-slot page tables (the default ``kv_layout="paged"``; HBM
  scales with live tokens, decode attention runs the flash-decode kernel),
  plus the ``donate``/``adopt`` handoff protocol between worker pools.
* :mod:`repro.serve.scheduler` — :class:`FleetRouter`: request queue +
  least-loaded admission across N replicas, requeue-on-defer, per-replica
  eviction/drain, arrival clock; ``ContinuousScheduler`` is the N=1 case.
* :mod:`repro.serve.static`    — the static-batch baseline arm, fused into
  a single dispatch (no per-token host sync; always the dense cache — the
  cross-layout parity oracle).

A/B: ``python -m benchmarks.perf_hillclimb --pair servepath`` (continuous vs
static), ``--pair decodepath`` (paged-flash vs dense-SDPA decode) and
``--pair fleetpath`` (routed disaggregated fleet vs monolithic engine).
"""
from repro.serve.engine import (
    DecodeState,
    DecodeWorker,
    EngineConfig,
    KVHandoff,
    PrefillWorker,
    ServeEngine,
    sample_tokens,
)
from repro.serve.kv_pool import KVPool
from repro.serve.scheduler import (
    Completion,
    ContinuousScheduler,
    FleetRouter,
    ManualClock,
    MonotonicClock,
    Request,
)
from repro.serve.static import make_static_generator, static_generate

__all__ = [
    "DecodeState",
    "DecodeWorker",
    "EngineConfig",
    "KVHandoff",
    "KVPool",
    "PrefillWorker",
    "ServeEngine",
    "sample_tokens",
    "Completion",
    "ContinuousScheduler",
    "FleetRouter",
    "ManualClock",
    "MonotonicClock",
    "Request",
    "make_static_generator",
    "static_generate",
]

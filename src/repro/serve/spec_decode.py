"""Speculative decoding for the continuous-batching engine.

A small **drafter** model proposes ``k`` greedy tokens per step from its own
dense per-slot cache; the target :class:`repro.serve.engine.DecodeWorker`
verifies all of them (plus one bonus position) in ONE batched
:func:`repro.models.lm_extend` forward and accepts the longest run that
matches its own greedy choices. Every emitted token is the TARGET's argmax —
**greedy token parity with the non-speculative engine is the contract**; the
drafter only decides how many target tokens one dispatch can certify, never
what they are. Per verify step the target runs one (S, k+1)-token forward
instead of up to ``k+1`` single-token decodes, so a well-matched drafter
turns memory-bound decode latency into compute the small model prepays.

Rollback discipline (why the gates below exist):

* the TARGET writes draft KV at ``pos..pos+k`` during verify; rejected
  positions are never attended (the causal mask stops at each query) and the
  next verify's write range always covers them — a full attention cache
  rolls back for free. An SWA ring does NOT: wrapped writes alias earlier
  positions, so spec mode requires a full cache (``_require_extend_capable``)
  — and a recurrent carry cannot roll back at all.
* the DRAFTER's dense cache holds the accepted prefix exactly (a draft is
  only "kept" where it matched the target), garbage past the new position is
  overwritten before it is ever attended — the same write-before-attend
  invariant the bucketed prefill relies on. The drafter must therefore also
  be attention-only with a full cache; pure-SSM drafters are rejected at
  construction, not mid-serving.

The device step/proposed/accepted counters ride the engine's existing
once-per-chunk host sync — speculative serving adds ZERO extra transfers.
"""
from __future__ import annotations

from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import group_pattern, init_lm_state, lm_decode, lm_extend, lm_prefill


class SpecDecoder:
    """Drafter-side state + the fused draft/verify chunk program. Owned by a
    :class:`repro.serve.engine.DecodeWorker` (``ecfg.spec_k > 0``); the
    worker delegates ``decode_chunk``/``sync`` here and forwards every
    admission so the drafter can prefill its own cache."""

    def __init__(self, worker, dcfg, dparams, k: int):
        non_attn = sorted({m for m, _ in group_pattern(dcfg) if m != "attn"})
        if non_attn:
            raise ValueError(
                f"drafter {dcfg.name}: speculative drafting requires attention-"
                f"only mixers, found {non_attn} — a recurrent carry cannot roll "
                "back past a rejected draft"
            )
        if dcfg.sliding_window > 0:
            raise ValueError(
                f"drafter {dcfg.name}: sliding_window={dcfg.sliding_window} makes "
                "the drafter cache a ring — stale rejected-draft writes would "
                "alias earlier positions after rollback. Draft with a full-"
                "attention config."
            )
        if dcfg.vocab_size != worker.cfg.vocab_size:
            raise ValueError(
                f"drafter {dcfg.name} vocab ({dcfg.vocab_size}) != target "
                f"{worker.cfg.name} vocab ({worker.cfg.vocab_size}): drafted ids "
                "would be meaningless to the verifier — pick a same-tokenizer "
                "drafter"
            )
        self.worker = worker
        self.dcfg = dcfg
        self.k = int(k)
        # one verify certifies up to k+1 tokens, so a chunk of decode_chunk
        # token-steps needs ~decode_chunk/(k+1) verify steps; the worker's
        # page planning uses `horizon` (tokens a chunk may emit)
        self.steps = max(1, worker.ecfg.decode_chunk // (self.k + 1))
        self.horizon = self.steps * (self.k + 1)
        if worker.mesh is not None:
            from repro.serve.engine import _shard_params

            dparams = _shard_params(dparams, worker.mesh)
        self.dparams = dparams
        self._draft: Any = None
        self._proposed = jnp.zeros((), jnp.int32)
        self._accepted = jnp.zeros((), jnp.int32)
        self._nsteps = jnp.zeros((), jnp.int32)
        donate = () if jax.default_backend() == "cpu" else (2, 3)
        self._chunk_jit = jax.jit(self._chunk_fn, donate_argnums=donate)
        self._prefill_jit = jax.jit(self._prefill_fn)

    # -- device programs ----------------------------------------------------

    def _prefill_fn(self, dparams, tokens, slots, draft):
        """Drafter prompt prefill for one admitted group: fill a fresh
        (N, max_seq) dense state and splice each row onto its slot. Compiled
        per (N, bucket) like the target's own prefill. Pad-tail garbage past
        each true length is overwritten by sequential drafting before it is
        ever attended."""
        e = self.worker.ecfg
        n = tokens.shape[0]
        st1 = init_lm_state(self.dcfg, n, e.max_seq)
        _, st1 = lm_prefill(dparams, self.dcfg, {"tokens": tokens}, st1)

        def splice(big, one):
            for i in range(n):
                big = jax.lax.dynamic_update_slice(
                    big,
                    jax.lax.dynamic_slice_in_dim(one, i, 1, axis=1).astype(big.dtype),
                    (0, slots[i]) + (0,) * (big.ndim - 2),
                )
            return big

        return jax.tree_util.tree_map(splice, draft, st1)

    def _chunk_fn(self, params, dparams, ds, draft, proposed, accepted, nsteps):
        """Up to ``steps`` draft→verify rounds in ONE dispatch. Each round:
        the drafter greedily unrolls k tokens from the batch's last tokens,
        the target scores ``[last_tok, d_1..d_k]`` in one extend, and the
        longest draft run matching the target's own argmax is emitted (plus
        the bonus token the verify got for free). Emission replicates the
        non-speculative chunk's masking token-for-token, so budgets, EOS and
        output rows behave identically — only the dispatch count differs."""
        w = self.worker
        cfg, dcfg, e, k = w.cfg, self.dcfg, w.ecfg, self.k
        rows = jnp.arange(e.max_slots, dtype=jnp.int32)

        def cond(carry):
            i, s, d, p, a, ns = carry
            return (i < self.steps) & jnp.any(s.active)

        def body(carry):
            i, s, d, p, a, ns = carry
            # 1) draft: k greedy single-token steps (unrolled; the drafter is
            # small by design). Inactive slots ride along rewriting their
            # frozen position in their OWN dense rows — harmless, as in the
            # non-speculative chunk.
            dt, dpos, drafts = s.last_tok, s.pos, []
            for _ in range(k):
                dlog, d = lm_decode(dparams, dcfg, dt, d, dpos)
                nxt = jnp.argmax(dlog[:, -1], axis=-1).astype(jnp.int32)  # (S,)
                drafts.append(nxt)
                dt, dpos = nxt[:, None], dpos + 1
            # one extra cache-fill step: when every draft is accepted plus
            # the bonus token, the next round resumes at pos+k+1 — position
            # pos+k (token d_k) must already be in the drafter's cache or it
            # would draft against a hole and never be accepted again
            _, d = lm_decode(dparams, dcfg, dt, d, dpos)
            dmat = jnp.stack(drafts, axis=1)  # (S, k)
            # 2) verify: ONE target forward over [last_tok, d_1..d_k] at
            # pos..pos+k. tgt[:, j] is the target's greedy choice after
            # consuming x[:, :j+1] — exactly what the non-spec engine would
            # have sampled at that step, provided all earlier drafts matched.
            x = jnp.concatenate([s.last_tok, dmat], axis=1)  # (S, k+1)
            vlog, kv = lm_extend(params, cfg, x, s.kv, s.pos, s.page_table)
            tgt = jnp.argmax(vlog, axis=-1).astype(jnp.int32)  # (S, k+1)
            match = (dmat == tgt[:, :k]).astype(jnp.int32)
            n_acc = jnp.cumprod(match, axis=1).sum(axis=1)  # (S,) in [0, k]
            p = p + k * jnp.sum(s.active.astype(jnp.int32))
            a = a + jnp.sum(jnp.where(s.active, n_acc, 0))
            ns = ns + 1

            # 3) emit tgt[:, 0..n_acc] per slot through the SAME per-token
            # masking as the non-speculative body (budget, max_new, EOS) —
            # candidate j simply "doesn't happen" for slots whose accepted
            # run ended earlier, like an inactive slot skipping a step
            def emit(j, c):
                out, n_out, act, last, pos = c
                tok = tgt[:, j]
                step = act & (j <= n_acc)
                write = step & (n_out < e.max_new)
                idx = jnp.minimum(n_out, e.max_new - 1)
                out = out.at[rows, idx].set(jnp.where(write, tok, out[rows, idx]))
                n_out = n_out + write.astype(jnp.int32)
                finished = n_out >= s.budget
                if e.eos_token >= 0:
                    finished |= (tok == e.eos_token) & step
                last = jnp.where(step[:, None], tok[:, None], last)
                pos = pos + step.astype(jnp.int32)
                return out, n_out, act & ~finished, last, pos

            out, n_out, active, last_tok, pos = jax.lax.fori_loop(
                0, k + 1, emit, (s.out, s.n_out, s.active, s.last_tok, s.pos)
            )
            s = s._replace(
                kv=kv, last_tok=last_tok, pos=pos, active=active,
                out=out, n_out=n_out,
            )
            return i + 1, s, d, p, a, ns

        _, ds, draft, proposed, accepted, nsteps = jax.lax.while_loop(
            cond, body, (jnp.zeros((), jnp.int32), ds, draft, proposed, accepted, nsteps)
        )
        return ds, draft, proposed, accepted, nsteps

    # -- host API -----------------------------------------------------------

    def reset(self) -> None:
        """(Re)build the drafter's dense cache (all slots) and zero the
        device counters."""
        w = self.worker
        draft = init_lm_state(self.dcfg, w.ecfg.max_slots, w.ecfg.max_seq)
        if w.mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            from repro.sharding.partition import shard_engine_state

            # the drafter cache shards by the same /k, /v suffix rules as the
            # target's dense engine state (heads over the model axis)
            specs = shard_engine_state({"draft": draft}, mesh_axes=dict(w.mesh.shape))
            shardings = jax.tree_util.tree_map(
                lambda spec: NamedSharding(w.mesh, spec), specs["draft"],
                is_leaf=lambda s: isinstance(s, P),
            )
            draft = jax.device_put(draft, shardings)
        self._draft = draft
        self._proposed = jnp.zeros((), jnp.int32)
        self._accepted = jnp.zeros((), jnp.int32)
        self._nsteps = jnp.zeros((), jnp.int32)

    def on_admit(self, slots: List[int], token_rows: np.ndarray, true_lens) -> None:
        """Prefill the drafter's cache rows for an admitted group. The
        drafter shares no pages with anyone — it always consumes the FULL
        (bucket-padded) prompt, even when the target spliced its prefix."""
        self._draft = self._prefill_jit(
            self.dparams,
            jnp.asarray(np.asarray(token_rows, np.int32)),
            jnp.asarray(np.asarray(slots, np.int32)),
            self._draft,
        )

    def chunk(self) -> None:
        """One fused draft/verify chunk; replaces the worker's plain chunk."""
        w = self.worker
        with obs.span("serve.spec.verify", replica=w.replica):
            (w._state, self._draft, self._proposed, self._accepted,
             self._nsteps) = self._chunk_jit(
                w.params, self.dparams, w._state, self._draft,
                self._proposed, self._accepted, self._nsteps,
            )

    def sync(self):
        """The worker's host sync, with the draft counters riding the SAME
        device-to-host transfer. The stats mirrors are cumulative-since-reset
        (assigned, not incremented)."""
        s = self.worker._state
        active, n_out, p, a, ns = jax.device_get(
            (s.active, s.n_out, self._proposed, self._accepted, self._nsteps)
        )
        st = self.worker.stats
        st["draft_proposed"] = int(p)
        st["draft_accepted"] = int(a)
        st["spec_steps"] = int(ns)
        return active, n_out

"""Seeded synthetic request streams for serving benchmarks and tests.

Every serve A/B (``perf_hillclimb --pair servepath/decodepath/fleetpath/
specpath``) and the scheduler property tests need the same three stream
shapes: fixed-length prompts with ragged budgets, fully-ragged staggered
arrivals, and (for the prefix-cache path) hot-prefix traffic where a
fraction of prompts share a long common head. Centralizing them keeps the
draw ORDER stable — an A/B's two arms (and a property test's two engines)
must consume the identical stream, and the order RandomState values are
drawn in IS the stream definition.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.scheduler import Request


def ragged_stream(
    vocab_size: int,
    n: int,
    prompt_len: int,
    max_gen: int,
    *,
    seed: int = 0,
    budget_min: int = 8,
) -> Tuple[List[np.ndarray], List[int]]:
    """Fixed-length prompts + ragged budgets, the serve-pair workload.
    Draw order (all prompts, then the budget vector) is part of the
    contract: the perf pairs' historical numbers were produced by it."""
    rng = np.random.RandomState(seed)
    prompts = [
        rng.randint(0, vocab_size, size=prompt_len).astype(np.int32) for _ in range(n)
    ]
    budgets = [int(g) for g in rng.randint(budget_min, max_gen + 1, size=n)]
    return prompts, budgets


def hot_prefix_stream(
    vocab_size: int,
    n: int,
    prompt_len: int,
    max_gen: int,
    *,
    seed: int = 0,
    budget_min: int = 8,
    shared_fraction: float = 0.5,
    prefix_len: Optional[int] = None,
) -> Tuple[List[np.ndarray], List[int]]:
    """Like :func:`ragged_stream` but a ``shared_fraction`` of the prompts
    open with one common ``prefix_len``-token head (default: half the
    prompt) — the system-prompt-heavy traffic a radix prefix cache exists
    for. Shared requests are interleaved with cold ones (even indices hot)
    so admission sees the mix, not two phases."""
    if not 0.0 <= shared_fraction <= 1.0:
        raise ValueError(f"shared_fraction must be in [0, 1], got {shared_fraction}")
    pl = prompt_len // 2 if prefix_len is None else prefix_len
    if pl > prompt_len:
        raise ValueError(f"prefix_len {pl} exceeds prompt_len {prompt_len}")
    rng = np.random.RandomState(seed)
    head = rng.randint(0, vocab_size, size=pl).astype(np.int32)
    n_hot = int(round(n * shared_fraction))
    hot = {i for i in range(0, n, max(1, n // max(n_hot, 1)))} if n_hot else set()
    hot = set(sorted(hot)[:n_hot])
    prompts = []
    for i in range(n):
        body = rng.randint(0, vocab_size, size=prompt_len).astype(np.int32)
        if i in hot:
            body[:pl] = head
        prompts.append(body)
    budgets = [int(g) for g in rng.randint(budget_min, max_gen + 1, size=n)]
    return prompts, budgets


def with_arrivals(
    prompts: Sequence[np.ndarray], budgets: Sequence[int], dt: float
) -> List[Request]:
    """Stamp a prompt/budget stream into :class:`Request`s arriving every
    ``dt`` seconds — the re-stamping step every calibrated A/B repeats with
    a different gap."""
    return [
        Request(rid=i, tokens=p, max_new_tokens=int(b), arrival=i * dt)
        for i, (p, b) in enumerate(zip(prompts, budgets))
    ]


def staggered_stream(
    vocab_size: int,
    n: int,
    *,
    seed: int = 3,
    prompt_range: Tuple[int, int] = (3, 14),
    budget_range: Tuple[int, int] = (2, 9),
    arrival_span: float = 3.0,
) -> List[Request]:
    """Fully-ragged staggered arrivals (the scheduler property-test
    workload): per request, draw length -> tokens -> budget -> arrival, in
    that order — the interleaved draw sequence the tests have always used."""
    rng = np.random.RandomState(seed)
    return [
        Request(
            rid=i,
            tokens=rng.randint(
                0, vocab_size, size=int(rng.randint(*prompt_range))
            ).astype(np.int32),
            max_new_tokens=int(rng.randint(*budget_range)),
            arrival=float(rng.uniform(0.0, arrival_span)),
        )
        for i in range(n)
    ]

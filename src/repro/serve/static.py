"""Static-batch generation: the legacy serving baseline, minus its per-token
host sync.

The original ``launch/serve.py`` loop dispatched one jitted decode per token
and ``np.asarray``-ed every sampled token back to host — O(gen) dispatches
and syncs per batch. Here prefill + the whole greedy/temperature decode is
ONE jitted program: tokens accumulate on device in a ``lax.scan`` and cross
to host once at the end. This is the ``--engine static`` baseline arm of the
``servepath`` A/B; the continuous engine (:mod:`repro.serve.engine`) beats
it by admitting work as it arrives instead of waiting for a full batch.

The static path always decodes against the DENSE per-slot cache (scalar
positions, small-SDPA attention) — it is the cross-layout parity oracle the
paged engine's token streams are pinned against in ``tests/test_serve.py``.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import init_lm_state, lm_decode, lm_prefill
from repro.serve.engine import sample_tokens


@functools.lru_cache(maxsize=64)
def make_static_generator(cfg, gen: int, temperature: float = 0.0):
    """Returns jitted ``f(params, batch, state, key) -> (B, gen) int32`` —
    prefill plus ``gen`` sampled tokens in a single dispatch. Cached per
    (cfg, gen, temperature) — ModelConfig is frozen/hashable — so repeated
    ``static_generate`` calls reuse one jit wrapper (and its compile cache)
    instead of re-tracing every batch."""

    def generate(params, batch: Dict[str, jax.Array], state, key):
        prompt_len = batch["tokens"].shape[1]
        base = prompt_len + (batch["prefix"].shape[1] if "prefix" in batch else 0)
        logits, state = lm_prefill(params, cfg, batch, state)
        key, k0 = jax.random.split(key)
        tok0 = sample_tokens(logits[:, -1], k0, temperature)

        def body(carry, pos):
            tok, st, k = carry
            lg, st = lm_decode(params, cfg, tok, st, pos)
            k, ks = jax.random.split(k)
            nxt = sample_tokens(lg[:, -1], ks, temperature)
            return (nxt[:, None], st, k), nxt

        (_, _, _), rest = jax.lax.scan(
            body, (tok0[:, None], state, key), base + jnp.arange(gen - 1, dtype=jnp.int32)
        )
        return jnp.concatenate([tok0[:, None], rest.T], axis=1)

    return jax.jit(generate)


def static_generate(
    params,
    cfg,
    batch: Dict[str, jax.Array],
    gen: int,
    *,
    temperature: float = 0.0,
    max_seq: Optional[int] = None,
    key: Optional[jax.Array] = None,
):
    """Convenience wrapper: build the decode state and run one static batch.
    ``batch["tokens"]``: (B, L) int32. Returns (B, gen) int32 on device."""
    b, prompt_len = batch["tokens"].shape
    prefix = batch["prefix"].shape[1] if "prefix" in batch else 0
    state = init_lm_state(cfg, b, (max_seq or (prompt_len + gen)) + prefix)
    key = jax.random.key(0) if key is None else key
    return make_static_generator(cfg, gen, temperature)(params, batch, state, key)

"""Request routing + scheduling for the serving fleet.

The host-side loop is now a :class:`FleetRouter` over N engine replicas
(each a :class:`repro.serve.engine.ServeEngine` — colocated or a
disaggregated prefill/decode pair, possibly on its own mesh slice):

  * requests become visible at their ``arrival`` time (a ``Clock`` — real
    monotonic time when serving, a :class:`ManualClock` in tests/benchmarks
    that only advances when the loop sleeps, keeping admission order
    deterministic) and are ROUTED to the least-loaded replica: load is the
    billed lifetime page count of everything resident plus everything
    queued there (slot counts in the dense layout), queue depth breaking
    ties — the cheapest signal that tracks actual KV occupancy instead of
    request counts, so one long-budget request doesn't look as light as
    one 8-token probe;
  * per replica, queued prompts are admitted into free slots in bursts (one
    batched prefill dispatch per bucket/power-of-two group, sealed into a
    KVHandoff and adopted by the replica's decode worker), interleaved with
    decode chunks over everything resident;
  * a queue head its replica cannot admit RIGHT NOW may **requeue-on-defer**
    to an idle replica that can — load is estimated at arrival, but pages
    drain at decode speed, so the estimate goes stale and a blocked head
    must not wait out a long resident burst while another replica sits
    empty;
  * after each chunk ONE host sync per replica reads the tiny per-slot
    status, finished sequences are drained (token row copied out, slot
    freed, pages back to that replica's pool — replicas never touch each
    other's pages) and freed slots are immediately refillable.

``ContinuousScheduler`` — the single-engine scheduler of earlier revisions
— is the N=1 router. Per decoded token the host does O(1/decode_chunk)
syncs per replica; the legacy static path did one ``np.asarray`` per token.

Completions record ``arrival``, ``admitted``, ``first_token`` and
``finished`` separately: a deferred request's queue wait
(``admitted - arrival``) is real latency the router caused, and folding it
into decode service time (as a single ``latency`` once did) hides exactly
the signal a router exists to optimize. ``first_token`` (stamped when the
admitting prefill's dispatch returns — the first token exists from that
prefill) splits TTFT out the same way: prefix splices and speculative wins
move TTFT and tokens-after-first differently, and a single latency number
averages them away.

Router stats are a :class:`repro.obs.StatsView` over the ``serve.router.*``
namespace (declared once in ``repro.obs.names`` next to the engine's keys —
the two literal dicts this file and the engine used to reset by hand could
silently drift). Completions additionally observe the
``serve.request.{latency,queue_wait,ttft}_s`` histograms, labelled by
replica, so a ``--metrics-out`` snapshot carries the percentile summary
without post-processing.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.obs import ROUTER_METRICS, MetricsRegistry, StatsView
from repro.serve.engine import ServeEngine


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # (L,) int32 prompt
    max_new_tokens: int
    arrival: float = 0.0  # seconds since scheduler start


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray  # (n,) int32 generated tokens (incl. first)
    arrival: float
    admitted: float  # when the admitting prefill dispatch began (not arrival!)
    finished: float
    replica: int = 0  # which fleet replica served it
    first_token: Optional[float] = None  # when the first token existed (TTFT)

    @property
    def latency(self) -> float:
        """End-to-end: arrival -> finished (queue wait + service)."""
        return self.finished - self.arrival

    @property
    def queue_wait(self) -> float:
        """Time spent queued/deferred before the admitting prefill ran —
        the router-attributable share of latency."""
        return self.admitted - self.arrival

    @property
    def service(self) -> float:
        """Time spent resident on a replica: admission -> finished."""
        return self.finished - self.admitted

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token: arrival -> the admitting prefill's return
        (every admission path samples the first token inside that dispatch).
        None on hand-built completions that never recorded the stamp."""
        if self.first_token is None:
            return None
        return self.first_token - self.arrival


class MonotonicClock:
    """Real wall-clock: origin at construction."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class ManualClock:
    """Deterministic test clock: time moves only via sleep()/advance(), plus
    an optional fixed ``tick`` per now() call — the tick stands in for decode
    wall time, so staggered arrivals become visible MID-decode and the
    admit-into-freed-slot path gets exercised deterministically."""

    def __init__(self, tick: float = 0.0):
        self._t = 0.0
        self._tick = tick

    def now(self) -> float:
        self._t += self._tick
        return self._t

    def sleep(self, dt: float) -> None:
        self._t += max(dt, 0.0)

    advance = sleep


class FleetRouter:
    """Least-loaded admission + eviction loop over N engine replicas;
    returns one Completion per request (tagged with its replica)."""

    def __init__(self, engines: Sequence[ServeEngine], clock=None,
                 registry: Optional[MetricsRegistry] = None):
        if not engines:
            raise ValueError("FleetRouter needs at least one engine replica")
        self.engines: List[ServeEngine] = list(engines)
        self.clock = clock
        if registry is None:
            # prefer the replicas' registry so router + engine series land in
            # one snapshot; engines built bare each carry a private registry,
            # in which case the router gets its own
            st = getattr(self.engines[0], "stats", None)
            registry = st.registry if isinstance(st, StatsView) else MetricsRegistry()
        self.registry = registry
        self.stats: StatsView = registry.view(ROUTER_METRICS)

    # -- routing policy -----------------------------------------------------

    def _bill(self, eng: ServeEngine, req: Request) -> int:
        return eng.request_load(len(req.tokens), req.max_new_tokens)

    def _load(self, i: int, queues: List[deque]) -> Tuple[int, int, int]:
        """A replica's admission-load key: billed lifetime pages of
        everything resident AND everything already queued there (queued
        work is committed load — ignoring it would shotgun a burst of
        arrivals onto whichever replica drained most recently), queue
        depth breaking page ties, replica index making the order total."""
        eng = self.engines[i]
        q = queues[i]
        return (
            eng.billed_pages() + sum(self._bill(eng, r) for r in q),
            len(q),
            i,
        )

    def _route(self, req: Request, queues: List[deque]) -> int:
        """Least-loaded replica among those that could EVER admit the
        request (an empty pool fits its lifetime bill). With prefix caching
        on, **prefix affinity** leads the key: replicas' radix caches are
        private, so a request lands where the most of its prompt is already
        resident (a splice there skips that much prefill AND allocation) —
        billed-page load only breaks affinity ties, which keeps cold traffic
        least-loaded-routed exactly as before."""
        feasible = [
            i
            for i, eng in enumerate(self.engines)
            if eng.can_ever_admit(len(req.tokens), req.max_new_tokens)
        ]
        if not feasible:
            raise RuntimeError(
                f"request rid={req.rid} (prompt {len(req.tokens)} tokens, "
                f"budget {req.max_new_tokens}) can never be admitted: its "
                "lifetime page bill outruns the EMPTY KV pool on every "
                "replica, so no amount of draining frees enough pages. Raise "
                "--pool-pages or shrink the prompt/budget."
            )
        self.stats["routed"] += 1
        hits = {i: self.engines[i].prefix_hit_pages(req.tokens) for i in feasible}
        best = min(feasible, key=lambda i: (-hits[i],) + self._load(i, queues))
        if hits[best] > 0:
            self.stats["affinity_hits"] += 1
        obs.instant("serve.route", rid=req.rid, replica=best, prefix_hits=hits[best])
        return best

    # -- the serving loop ---------------------------------------------------

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        clock = self.clock or MonotonicClock()
        for eng in self.engines:
            eng.reset()
        for k in self.stats:
            self.stats[k] = 0
        pending = deque(sorted(requests, key=lambda r: r.arrival))
        queues: List[deque] = [deque() for _ in self.engines]
        # per replica: slot -> (request, admitted_time, first_token_time)
        resident: List[dict] = [{} for _ in self.engines]
        done: List[Completion] = []

        def _admit(i: int, burst: List[Request]) -> None:
            # admitted is stamped BEFORE the prefill dispatch and first_token
            # AFTER it: the dispatch samples every admitted sequence's first
            # token, so the gap between the two stamps is prefill service —
            # part of TTFT but not of queue wait.
            t_admit = clock.now()
            with obs.span("serve.admit", replica=i, n=len(burst)):
                slots = self.engines[i].admit_many(
                    [(r.tokens, r.max_new_tokens) for r in burst]
                )
            t_first = clock.now()
            for slot, req in zip(slots, burst):
                resident[i][slot] = (req, t_admit, t_first)

        while pending or any(queues) or any(resident):
            now = clock.now()
            while pending and pending[0].arrival <= now:
                req = pending.popleft()
                queues[self._route(req, queues)].append(req)

            # per-replica burst admission: bounded by free slots AND (paged
            # layout) by free KV pages — excess requests stay queued and
            # admit when a drain returns capacity, instead of crashing
            for i, eng in enumerate(self.engines):
                if queues[i] and eng.free_slots:
                    n = eng.max_admissible(
                        [(r.tokens, r.max_new_tokens) for r in queues[i]]
                    )
                    if n:
                        _admit(i, [queues[i].popleft() for _ in range(n)])

            # requeue-on-defer: arrival-time routing goes stale as pages
            # drain — a queue head blocked on ITS replica moves to an IDLE
            # (empty-queue) replica that can admit it immediately. Only the
            # head moves (later entries would jump the arrival order) and
            # only to empty queues (a requeued request must admit now, not
            # trade one wait for another).
            for i, eng in enumerate(self.engines):
                if not queues[i]:
                    continue
                head = queues[i][0]
                pair = [(head.tokens, head.max_new_tokens)]
                if eng.max_admissible(pair):
                    continue  # admits here next tick; no defer to fix
                targets = [
                    j
                    for j, other in enumerate(self.engines)
                    if j != i and not queues[j] and other.max_admissible(pair)
                ]
                if targets:
                    j = min(targets, key=lambda j: self._load(j, queues))
                    queues[i].popleft()
                    _admit(j, [head])
                    self.stats["requeued"] += 1

            if any(resident):
                for i, eng in enumerate(self.engines):
                    if not resident[i]:
                        continue
                    eng.decode_chunk()
                    active, n_out = eng.sync()
                    t_done = clock.now()
                    for slot in [s for s in resident[i] if not active[s]]:
                        req, t_admit, t_first = resident[i].pop(slot)
                        toks = eng.fetch(slot, int(n_out[slot]))
                        comp = Completion(
                            rid=req.rid,
                            prompt_len=len(req.tokens),
                            tokens=toks,
                            arrival=req.arrival,
                            admitted=t_admit,
                            finished=t_done,
                            replica=i,
                            first_token=t_first,
                        )
                        self.registry.observe(
                            "serve.request.latency_s", comp.latency, replica=i
                        )
                        self.registry.observe(
                            "serve.request.queue_wait_s", comp.queue_wait, replica=i
                        )
                        self.registry.observe(
                            "serve.request.ttft_s", comp.ttft, replica=i
                        )
                        done.append(comp)
            elif pending and not any(queues):
                clock.sleep(pending[0].arrival - now)
        return sorted(done, key=lambda c: c.rid)


class ContinuousScheduler(FleetRouter):
    """The N=1 fleet: one engine, no routing choice — the single-engine
    scheduler earlier revisions had, preserved as the parity oracle the
    fleet tests compare against."""

    def __init__(self, engine: ServeEngine, clock=None):
        super().__init__([engine], clock)
        self.engine = engine

"""Request scheduler for the continuous-batching engine.

The host-side loop around :class:`repro.serve.engine.ServeEngine`:

  * requests become visible at their ``arrival`` time (a ``Clock`` — real
    monotonic time when serving, a :class:`ManualClock` in tests/benchmarks
    that only advances when the loop sleeps, keeping admission order
    deterministic);
  * queued prompts are admitted into free slots in bursts (one batched
    prefill dispatch per bucket/power-of-two group), interleaved with decode
    chunks over everything resident;
  * after each chunk ONE host sync reads the tiny per-slot status, finished
    sequences are drained (token row copied out, slot freed — and in the
    paged KV layout the slot's pages go back to the pool free list) and the
    freed slots are immediately refillable.

Per decoded token the host does O(1/decode_chunk) syncs — the legacy static
path did one ``np.asarray`` per token.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import ServeEngine


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # (L,) int32 prompt
    max_new_tokens: int
    arrival: float = 0.0  # seconds since scheduler start


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray  # (n,) int32 generated tokens (incl. first)
    arrival: float
    admitted: float
    finished: float

    @property
    def latency(self) -> float:
        return self.finished - self.arrival


class MonotonicClock:
    """Real wall-clock: origin at construction."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class ManualClock:
    """Deterministic test clock: time moves only via sleep()/advance(), plus
    an optional fixed ``tick`` per now() call — the tick stands in for decode
    wall time, so staggered arrivals become visible MID-decode and the
    admit-into-freed-slot path gets exercised deterministically."""

    def __init__(self, tick: float = 0.0):
        self._t = 0.0
        self._tick = tick

    def now(self) -> float:
        self._t += self._tick
        return self._t

    def sleep(self, dt: float) -> None:
        self._t += max(dt, 0.0)

    advance = sleep


class ContinuousScheduler:
    """Admission + eviction loop; returns one Completion per request."""

    def __init__(self, engine: ServeEngine, clock=None):
        self.engine = engine
        self.clock = clock

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        eng = self.engine
        clock = self.clock or MonotonicClock()
        eng.reset()
        pending = deque(sorted(requests, key=lambda r: r.arrival))
        queue: deque = deque()
        resident: Dict[int, tuple] = {}  # slot -> (request, admitted_time)
        done: List[Completion] = []

        while pending or queue or resident:
            now = clock.now()
            while pending and pending[0].arrival <= now:
                queue.append(pending.popleft())
            if queue and eng.free_slots:
                # burst size is bounded by free slots AND (paged layout) by
                # free KV pages — excess requests stay queued and admit when
                # a drain returns capacity, instead of crashing the run
                n = eng.max_admissible([(r.tokens, r.max_new_tokens) for r in queue])
                if n == 0 and not resident:
                    r = queue[0]
                    raise RuntimeError(
                        f"request rid={r.rid} (prompt {len(r.tokens)} tokens, "
                        f"budget {r.max_new_tokens}) can never be admitted: its "
                        "lifetime page bill outruns the EMPTY KV pool, so no "
                        "amount of draining frees enough pages. Raise --pool-pages "
                        "or shrink the prompt/budget."
                    )
                burst = [queue.popleft() for _ in range(n)]
                if burst:
                    slots = eng.admit_many([(r.tokens, r.max_new_tokens) for r in burst])
                    t_admit = clock.now()
                    for slot, req in zip(slots, burst):
                        resident[slot] = (req, t_admit)
            if resident:
                eng.decode_chunk()
                active, n_out = eng.sync()
                t_done = clock.now()
                for slot in [s for s in resident if not active[s]]:
                    req, t_admit = resident.pop(slot)
                    toks = eng.fetch(slot, int(n_out[slot]))
                    done.append(
                        Completion(
                            rid=req.rid,
                            prompt_len=len(req.tokens),
                            tokens=toks,
                            arrival=req.arrival,
                            admitted=t_admit,
                            finished=t_done,
                        )
                    )
            elif pending:
                clock.sleep(pending[0].arrival - now)
        return sorted(done, key=lambda c: c.rid)

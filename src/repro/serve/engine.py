"""Slot-based continuous-batching engine for the distilled server LM.

The engine owns a device-resident batched decode state: every request lives
in one of ``max_slots`` slots of the KV-cache / SSM-state pytree, with its
OWN position counter — :func:`repro.models.attention.attn_decode` accepts a
per-row position vector, so slots at different depths decode in one step.

The two jitted programs:

  * **admit** — prefill an admission burst of prompts (padded up to a
    ``prefill_bucket`` multiple so ragged lengths share compilations; the
    pad tail is never attended because decode overwrites position ``p``
    before reading it) in one dispatch per (bucket, power-of-two group),
    splice each row's state into its slot, and sample each first token from
    that row's true-last-prompt-position logits.
  * **decode chunk** — a ``lax.while_loop`` of up to ``decode_chunk`` steps:
    batched one-token decode over ALL slots, on-device greedy/temperature
    sampling, per-slot output accumulation and finish bookkeeping. Zero
    per-token host syncs — the host reads back only the tiny
    ``(active, n_out)`` vectors once per chunk (``sync``), and a finished
    request's token row once at eviction (``fetch``).

Inactive slots ride along in the batched decode (their position is frozen,
so they idempotently rewrite one cache slot) — that is the cost of a fixed
batch shape, and exactly what admission refills.

``stats`` counts dispatches and host syncs; tests pin host syncs = O(1) per
decode chunk, independent of chunk length and token count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_lm_state, lm_decode, lm_prefill


def sample_tokens(logits: jax.Array, key: jax.Array, temperature: float) -> jax.Array:
    """On-device sampling. logits: (B, V) -> (B,) int32. ``temperature <= 0``
    is greedy (argmax); otherwise temperature-scaled categorical."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits.astype(jnp.float32) / temperature, axis=-1).astype(
        jnp.int32
    )


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Continuous-batching knobs (the model itself comes from ModelConfig)."""

    max_slots: int = 4  # concurrent sequences resident on device
    max_seq: int = 256  # per-slot cache length (prompt + generation)
    max_new: int = 64  # output-buffer width (per-request budget <= this)
    decode_chunk: int = 16  # decode steps per dispatch (and per host sync)
    prefill_bucket: int = 32  # prompts pad up to a multiple of this
    temperature: float = 0.0  # 0 => greedy
    eos_token: int = -1  # <0 => disabled (synthetic streams have no EOS)
    seed: int = 0


class DecodeState(NamedTuple):
    """The device-resident per-slot state threaded through decode chunks."""

    kv: Any  # model state pytree, leaves (G, max_slots, ...)
    last_tok: jax.Array  # (S, 1) int32 — last sampled token per slot
    pos: jax.Array  # (S,) int32 — position the next decode step writes
    active: jax.Array  # (S,) bool
    out: jax.Array  # (S, max_new) int32 — generated tokens per slot
    n_out: jax.Array  # (S,) int32 — tokens generated so far
    budget: jax.Array  # (S,) int32 — per-request generation budget
    rng: jax.Array  # PRNG key for sampling


class ServeEngine:
    """Device side of the serving stack; :class:`repro.serve.scheduler.
    ContinuousScheduler` drives it from the request queue."""

    def __init__(self, cfg, params, ecfg: EngineConfig):
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only: nothing to decode")
        if cfg.frontend == "vision":
            raise ValueError(
                f"{cfg.name} needs per-request vision prefix embeddings, which "
                "the slot engine does not thread through admission yet; serve "
                "vlm archs with the static batch path"
            )
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.free_slots: List[int] = list(range(ecfg.max_slots))
        self._state: Optional[DecodeState] = None
        # jit caches per abstract (N, bucket) tokens shape — one wrapper serves
        # every admission-burst size/bucket combination
        self._admit_jit = jax.jit(self._admit_fn)
        donate = () if jax.default_backend() == "cpu" else (1,)
        self._chunk_jit = jax.jit(self._chunk_fn, donate_argnums=donate)
        self.reset()

    # -- device programs ----------------------------------------------------

    def _admit_fn(self, params, ds: DecodeState, tokens, slots, true_lens, budgets):
        """Batched admission: prefill N prompts (N is a compile-time constant
        per call — the scheduler's admission burst) in ONE dispatch and
        splice each row into its slot. tokens: (N, Lb); slots/true_lens/
        budgets: (N,) int32. The sampling key comes from the state's own rng
        chain — no host-side key dispatch per admission."""
        cfg, e = self.cfg, self.ecfg
        n = tokens.shape[0]
        rng, key = jax.random.split(ds.rng)
        st1 = init_lm_state(cfg, n, e.max_seq)
        logits, st1 = lm_prefill(params, cfg, {"tokens": tokens}, st1, last_index=true_lens - 1)
        kv = ds.kv
        for i in range(n):  # n <= max_slots: unrolled per-row state splice
            kv = jax.tree_util.tree_map(
                lambda big, one: jax.lax.dynamic_update_slice(
                    big,
                    jax.lax.dynamic_slice_in_dim(one, i, 1, axis=1).astype(big.dtype),
                    (0, slots[i]) + (0,) * (big.ndim - 2),
                ),
                kv,
                st1,
            )
        toks0 = sample_tokens(logits[:, 0], key, e.temperature)  # (N,)
        return DecodeState(
            kv=kv,
            last_tok=ds.last_tok.at[slots, 0].set(toks0),
            pos=ds.pos.at[slots].set(true_lens),
            active=ds.active.at[slots].set(budgets > 1),
            out=ds.out.at[slots].set(0).at[slots, 0].set(toks0),
            n_out=ds.n_out.at[slots].set(1),
            budget=ds.budget.at[slots].set(budgets),
            rng=rng,
        )

    def _chunk_fn(self, params, ds: DecodeState):
        cfg, e = self.cfg, self.ecfg
        rows = jnp.arange(e.max_slots, dtype=jnp.int32)

        def cond(carry):
            i, s = carry
            return (i < e.decode_chunk) & jnp.any(s.active)

        def body(carry):
            i, s = carry
            logits, kv = lm_decode(params, cfg, s.last_tok, s.kv, s.pos)
            rng, ks = jax.random.split(s.rng)
            nxt = sample_tokens(logits[:, -1], ks, e.temperature)
            write = s.active & (s.n_out < e.max_new)
            idx = jnp.minimum(s.n_out, e.max_new - 1)
            out = s.out.at[rows, idx].set(jnp.where(write, nxt, s.out[rows, idx]))
            n_out = s.n_out + write.astype(jnp.int32)
            finished = n_out >= s.budget
            if e.eos_token >= 0:
                finished |= (nxt == e.eos_token) & s.active
            return i + 1, DecodeState(
                kv=kv,
                last_tok=jnp.where(s.active[:, None], nxt[:, None], s.last_tok),
                pos=s.pos + s.active.astype(jnp.int32),
                active=s.active & ~finished,
                out=out,
                n_out=n_out,
                budget=s.budget,
                rng=rng,
            )

        _, ds = jax.lax.while_loop(cond, body, (jnp.zeros((), jnp.int32), ds))
        return ds

    # -- host API -----------------------------------------------------------

    def reset(self) -> None:
        """(Re)build the device state: all slots free, caches zeroed, stats
        zeroed (so a warm-up run never contaminates timed counters)."""
        cfg, e = self.cfg, self.ecfg
        self.free_slots = list(range(e.max_slots))
        self.stats: Dict[str, int] = {
            "admitted": 0,
            "prefill_dispatches": 0,
            "decode_chunks": 0,
            "host_syncs": 0,
            "evicted": 0,
        }
        self._state = DecodeState(
            kv=init_lm_state(cfg, e.max_slots, e.max_seq),
            last_tok=jnp.zeros((e.max_slots, 1), jnp.int32),
            pos=jnp.zeros((e.max_slots,), jnp.int32),
            active=jnp.zeros((e.max_slots,), bool),
            out=jnp.zeros((e.max_slots, e.max_new), jnp.int32),
            n_out=jnp.zeros((e.max_slots,), jnp.int32),
            budget=jnp.zeros((e.max_slots,), jnp.int32),
            rng=jax.random.key(e.seed),
        )

    def bucket_len(self, prompt_len: int) -> int:
        if self.cfg.family in ("ssm", "hybrid"):
            # a recurrent carry (mamba/xlstm state) absorbs pad tokens — the
            # prefill must stop exactly at the prompt end, so recurrent archs
            # compile one prefill per distinct prompt length instead of per
            # bucket. Attention caches are position-addressed: the pad tail
            # is overwritten before it is ever attended, so bucketing is safe.
            return prompt_len
        b = self.ecfg.prefill_bucket
        lb = min(-(-prompt_len // b) * b, self.ecfg.max_seq)
        if self.cfg.sliding_window > 0:
            # the SWA cache is a ring of min(window, max_seq) slots holding
            # the LAST cache-len prefill positions; padding past the ring
            # length would evict real prompt tokens in favor of pad garbage.
            cl = min(self.cfg.sliding_window, self.ecfg.max_seq)
            lb = prompt_len if prompt_len > cl else min(lb, cl)
        return lb

    def admit(self, tokens: np.ndarray, max_new_tokens: int) -> int:
        """Prefill one prompt (1-D int32) into a free slot; returns its id."""
        return self.admit_many([(tokens, max_new_tokens)])[0]

    def admit_many(self, requests) -> List[int]:
        """Admit several prompts; returns their slots, input-aligned.

        Prompts sharing a bucket length prefill together: each group is
        split into power-of-two admission batches (4+2+1…) so the set of
        compiled (bucket, N) programs stays O(log max_slots) per bucket
        instead of one per burst size — a freed-slot refill after warm-up
        never hits the compiler."""
        e = self.ecfg
        prepped = []
        for tokens, max_new_tokens in requests:
            tokens = np.asarray(tokens, np.int32).reshape(-1)
            if len(tokens) + max_new_tokens > e.max_seq:
                raise ValueError(
                    f"prompt ({len(tokens)}) + budget ({max_new_tokens}) exceeds max_seq={e.max_seq}"
                )
            if not 1 <= max_new_tokens <= e.max_new:
                raise ValueError(
                    f"max_new_tokens must be in [1, {e.max_new}], got {max_new_tokens}"
                )
            prepped.append((tokens, max_new_tokens))
        if len(prepped) > len(self.free_slots):
            raise RuntimeError(
                f"{len(prepped)} admissions but only {len(self.free_slots)} free slots"
            )
        by_bucket: Dict[int, List[int]] = {}
        for i, (tokens, _) in enumerate(prepped):
            by_bucket.setdefault(self.bucket_len(len(tokens)), []).append(i)
        slots = [0] * len(prepped)
        for lb, idxs in by_bucket.items():
            while idxs:
                n = 1 << (len(idxs).bit_length() - 1)  # largest pow2 <= len
                group, idxs = idxs[:n], idxs[n:]
                padded = np.zeros((n, lb), np.int32)
                lens = np.zeros((n,), np.int32)
                buds = np.zeros((n,), np.int32)
                gslots = [self.free_slots.pop() for _ in group]
                for j, i in enumerate(group):
                    tokens, budget = prepped[i]
                    padded[j, : len(tokens)] = tokens
                    lens[j], buds[j] = len(tokens), budget
                    slots[i] = gslots[j]
                self._state = self._admit_jit(
                    self.params,
                    self._state,
                    jnp.asarray(padded),
                    jnp.asarray(gslots, jnp.int32),
                    jnp.asarray(lens),
                    jnp.asarray(buds),
                )
                self.stats["admitted"] += n
                self.stats["prefill_dispatches"] += 1
        return slots

    def warmup(self, prompt: np.ndarray, budget: int = 2) -> None:
        """Compile every admission program a serving run can hit — one per
        power-of-two burst size up to ``max_slots`` for ``prompt``'s bucket —
        plus the decode-chunk program, then reset. Without this, the first
        burst of a previously-unseen size pays XLA compilation mid-serving."""
        budget = min(budget, self.ecfg.max_new)
        n = 1
        while n <= self.ecfg.max_slots:
            self.reset()
            self.admit_many([(prompt, budget)] * n)
            self.decode_chunk()
            self.sync()
            n *= 2
        self.reset()

    def decode_chunk(self) -> None:
        """Up to ``decode_chunk`` batched decode steps in ONE dispatch."""
        self._state = self._chunk_jit(self.params, self._state)
        self.stats["decode_chunks"] += 1

    def sync(self):
        """The once-per-chunk host sync: (active, n_out) as numpy, fetched
        in a single device-to-host transfer."""
        active, n_out = jax.device_get((self._state.active, self._state.n_out))
        self.stats["host_syncs"] += 1
        return active, n_out

    def fetch(self, slot: int, n_out: int) -> np.ndarray:
        """Copy a finished slot's generated tokens to host and free the slot."""
        toks = np.asarray(self._state.out[slot])[:n_out]
        self.free_slots.append(slot)
        self.stats["evicted"] += 1
        return toks

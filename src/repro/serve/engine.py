"""Serving engines for the distilled server LM: a prefill/decode worker pair
composed either colocated (the classic :class:`ServeEngine`) or
disaggregated behind an explicit KV handoff.

The monolithic slot engine of earlier revisions is now TWO jitted programs
owned by two workers:

  * :class:`PrefillWorker` — **compute-bound admission**: prefills a bucketed
    burst of prompts in one dispatch (padded up to a ``prefill_bucket``
    multiple so ragged lengths share compilations; the pad tail is never
    attended because decode overwrites position ``p`` before reading it),
    samples each row's first token from its true-last-prompt-position logits,
    and SEALS the result into a :class:`KVHandoff`: attention KV re-viewed as
    page units ``(G, N, n_alloc, page, KH, hd)`` plus the dense rows of any
    recurrent mixer state. A staging :class:`~repro.serve.kv_pool.KVPool`
    accounts the in-flight handoff pages (backpressure: a prefill worker
    cannot run unboundedly ahead of decode capacity); the sealed buffers
    themselves travel with the handoff.
  * :class:`DecodeWorker` — **bandwidth-bound decode**: owns the device-
    resident per-slot :class:`DecodeState` (each request lives in one of
    ``max_slots`` slots with its OWN position counter), ``adopt``s handoffs
    (pool ids allocated in ITS pool, sealed pages scattered into ITS buffers
    — pure data movement, no model forward), and runs ``lax.while_loop``
    decode chunks with on-device sampling. The host reads back only the tiny
    ``(active, n_out)`` vectors once per chunk (``sync``) and a finished
    request's token row once at eviction (``fetch``).

Because adoption is data movement, a request prefilled by one worker can
land on a DIFFERENT worker's pool than it decodes from — that is the
disaggregation seam (``EngineConfig.disagg``; paged-only, since the dense
per-slot rectangle has no page units to hand off). The classic
:class:`ServeEngine` survives as a thin colocated composition of the two
workers sharing one stats dict — the parity oracle and the default on one
device. Both compositions run the SAME two programs, so fleet==engine greedy
token parity is structural, not coincidental.

Two KV layouts (``EngineConfig.kv_layout``): **paged** (default) — a shared
page pool; admission allocates the pages the bucketed prefill fills, decode
appends a page when a slot's position crosses a page boundary (checked once
per chunk, host-side), eviction returns the slot's pages, and decode
attention takes the page-table view through the flash-decode dispatch.
**dense** — the per-slot ``(slots, cache_len, ...)`` rectangle attending via
the small SDPA path; the parity baseline, and what pure-SSM archs (nothing
to page) silently degrade to.

Inactive slots ride along in the batched decode (their position is frozen,
so they idempotently rewrite one cache location). The dense layout absorbs
those writes in the slot's own row; the paged layout re-aims every
idle/evicted slot's page-table row at the pool's never-allocated SCRATCH
page before the next chunk, because its old pages may already belong to
another slot (a stale row was a real cross-slot clobber, pinned by
``test_engine_paged_idle_slots_cannot_clobber``).

``stats`` counts dispatches and host syncs; tests pin host syncs = O(1) per
decode chunk, independent of chunk length and token count. The stats are no
longer a free dict: they are a :class:`repro.obs.StatsView` over a metrics
registry (``serve.*`` namespace, declared once in :mod:`repro.obs.names`,
labelled with the replica id) — a bare engine gets a private registry, a
fleet launcher passes one shared registry so replicas aggregate. Host-side
spans (``obs.span``) bracket every hot-path action (prefill → handoff →
adopt → decode chunk → sync); they never force a device sync, so the
O(1)-syncs-per-chunk contract is telemetry-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, MutableMapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.models import group_pattern, init_lm_state, lm_decode, lm_extend, lm_prefill
from repro.obs import KV_GAUGES, SERVE_ENGINE_METRICS, MetricsRegistry, StatsView
from repro.serve.kv_pool import KVPool
from repro.sharding import infer_param_specs, shard_engine_state

KV_LAYOUTS = ("paged", "dense")


def sample_tokens(logits: jax.Array, key: jax.Array, temperature: float) -> jax.Array:
    """On-device sampling. logits: (B, V) -> (B,) int32. ``temperature <= 0``
    is greedy (argmax); otherwise temperature-scaled categorical."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits.astype(jnp.float32) / temperature, axis=-1).astype(
        jnp.int32
    )


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Continuous-batching knobs (the model itself comes from ModelConfig).

    Construction fails fast on inconsistent paged-KV knobs — BEFORE any
    device allocation (same contract as the launch arg audit)."""

    max_slots: int = 4  # concurrent sequences resident on device
    max_seq: int = 256  # per-slot cache length (prompt + generation)
    max_new: int = 64  # output-buffer width (per-request budget <= this)
    decode_chunk: int = 16  # decode steps per dispatch (and per host sync)
    prefill_bucket: int = 32  # prompts pad up to a multiple of this
    temperature: float = 0.0  # 0 => greedy
    eos_token: int = -1  # <0 => disabled (synthetic streams have no EOS)
    seed: int = 0
    kv_layout: str = "paged"  # paged (KVPool + flash-decode) | dense (SDPA)
    page_size: int = 16  # tokens per KV page (power of two)
    pool_pages: int = 0  # pool capacity; 0 => max_slots × full per-slot width
    disagg: bool = False  # prefill and decode as separate fleet workers
    prefix_cache: bool = False  # radix prefix cache over refcounted pages
    spec_k: int = 0  # speculative decoding: drafts per verify step (0 = off)

    def __post_init__(self):
        for field in ("max_slots", "max_seq", "max_new", "decode_chunk", "prefill_bucket"):
            if getattr(self, field) < 1:
                raise ValueError(f"EngineConfig.{field} must be >= 1, got {getattr(self, field)}")
        if self.kv_layout not in KV_LAYOUTS:
            raise ValueError(
                f"EngineConfig.kv_layout must be one of {KV_LAYOUTS}, got {self.kv_layout!r}"
            )
        if self.spec_k < 0:
            raise ValueError(f"EngineConfig.spec_k must be >= 0, got {self.spec_k}")
        if self.spec_k and self.temperature > 0.0:
            raise ValueError(
                "speculative decoding (spec_k > 0) requires temperature=0: the "
                "accept-longest-greedy-run verify is a GREEDY parity contract; "
                "sampled drafts would need rejection sampling the engine does "
                "not implement. Drop --spec-decode or set --temperature 0."
            )
        if self.kv_layout != "paged":
            if self.disagg:
                raise ValueError(
                    'disagg=True requires kv_layout="paged": the prefill->decode '
                    "handoff moves sealed KV PAGES between worker pools, and the "
                    "dense per-slot rectangle has no page units to hand off. Drop "
                    "--disagg or use --kv-layout paged."
                )
            if self.prefix_cache:
                raise ValueError(
                    'prefix_cache=True requires kv_layout="paged": prefix sharing '
                    "IS page-table splicing — the dense per-slot rectangle has no "
                    "page units to share. Drop --prefix-cache or use --kv-layout "
                    "paged."
                )
            return
        if self.page_size < 1 or (self.page_size & (self.page_size - 1)):
            raise ValueError(
                f"EngineConfig.page_size must be a power of two, got {self.page_size} "
                "(page offsets are bit-sliced from positions; the pool and the "
                "flash-decode BlockSpecs both assume it)"
            )
        if self.max_seq % self.page_size:
            raise ValueError(
                f"EngineConfig.max_seq={self.max_seq} must be a multiple of "
                f"page_size={self.page_size} so the page-table extent recovers the "
                "logical cache length exactly (round max_seq up)"
            )
        if self.pool_pages and self.pool_pages < self.max_slots:
            raise ValueError(
                f"pool_pages={self.pool_pages} < max_slots={self.max_slots}: "
                "every live slot needs at least one page"
            )
        # the pool-vs-burst floor needs the MODEL's cache length (an SWA ring
        # bills far fewer pages than bucket_min tokens suggest), so it lives
        # in KVPool.__init__ — still pure-host, still pre-device


class DecodeState(NamedTuple):
    """The device-resident per-slot state threaded through decode chunks."""

    kv: Any  # model state pytree, leaves (G, max_slots, ...) or paged pools
    last_tok: jax.Array  # (S, 1) int32 — last sampled token per slot
    pos: jax.Array  # (S,) int32 — position the next decode step writes
    active: jax.Array  # (S,) bool
    out: jax.Array  # (S, max_new) int32 — generated tokens per slot
    n_out: jax.Array  # (S,) int32 — tokens generated so far
    budget: jax.Array  # (S,) int32 — per-request generation budget
    rng: jax.Array  # PRNG key for sampling
    page_table: jax.Array  # (S, W) int32 — per-slot page ids ((S, 1) dummy when dense)


class KVHandoff(NamedTuple):
    """One sealed prefill burst in flight between a prefill worker and a
    decode worker. ``sealed`` carries the page-unit attention KV (and dense
    rows for recurrent mixers); ids are pool-local and never travel — the
    adopting pool assigns its own."""

    sealed: Any  # device pytree; attn leaves (G, N, n_alloc, page, KH, hd)
    first_tok: jax.Array  # (N,) int32 — first sampled token per row
    true_lens: np.ndarray  # (N,) host — true prompt lengths
    budgets: np.ndarray  # (N,) host — generation budgets
    n_alloc: int  # sealed pages per row (0 for the dense layout)
    staging_id: int  # staging-pool reservation on the source (-1 when none)
    source: Any  # the PrefillWorker that sealed this burst
    tokens: Any = None  # (N, bucket) host prompt tokens — the adopting side
    # feeds them to the speculative drafter's own prefill (and could re-derive
    # prefix-cache keys); pure metadata, never needed by the target model

    @property
    def n(self) -> int:
        return len(self.true_lens)


def bucket_len(cfg, ecfg: EngineConfig, prompt_len: int) -> int:
    """The padded prefill length a prompt compiles under."""
    if cfg.family in ("ssm", "hybrid"):
        # a recurrent carry (mamba/xlstm state) absorbs pad tokens — the
        # prefill must stop exactly at the prompt end, so recurrent archs
        # compile one prefill per distinct prompt length instead of per
        # bucket. Attention caches are position-addressed: the pad tail
        # is overwritten before it is ever attended, so bucketing is safe.
        return prompt_len
    b = ecfg.prefill_bucket
    lb = min(-(-prompt_len // b) * b, ecfg.max_seq)
    if cfg.sliding_window > 0:
        # the SWA cache is a ring of min(window, max_seq) slots holding
        # the LAST cache-len prefill positions; padding past the ring
        # length would evict real prompt tokens in favor of pad garbage.
        cl = min(cfg.sliding_window, ecfg.max_seq)
        lb = prompt_len if prompt_len > cl else min(lb, cl)
    return lb


def _engine_layout(cfg, ecfg: EngineConfig) -> str:
    has_attn = any(mixer == "attn" for mixer, _ in group_pattern(cfg))
    # pure-SSM archs have no KV to page: degrade to the dense state layout
    return ecfg.kv_layout if has_attn else "dense"


def _require_extend_capable(cfg, ecfg: EngineConfig, feature: str) -> None:
    """Both prefix sharing and speculative verify run :func:`lm_extend` —
    "prefill semantics starting mid-cache" — which only attention caches
    support: a recurrent carry cannot start mid-sequence (splice) or roll
    back rejected positions (verify), and an SWA ring wraps writes into
    pages another request may share. Fail fast, pre-device."""
    from repro.models.attention import cache_len

    non_attn = [m for m, _ in group_pattern(cfg) if m != "attn"]
    if non_attn:
        raise ValueError(
            f"{cfg.name}: {feature} requires attention-only mixers, found "
            f"{sorted(set(non_attn))} — a recurrent carry cannot be spliced "
            "mid-sequence or rolled back after a rejected draft"
        )
    if cache_len(cfg, ecfg.max_seq) != ecfg.max_seq:
        raise ValueError(
            f"{cfg.name}: {feature} requires a full (non-ring) KV cache, but "
            f"sliding_window={cfg.sliding_window} < max_seq={ecfg.max_seq} "
            "makes decode writes wrap into earlier pages — a wrapped write "
            "would land in a page another request shares"
        )


def _fresh_stats(registry: Optional[MetricsRegistry] = None, replica: int = 0) -> StatsView:
    """One engine's stats: a dict-shaped view over the ``serve.*`` metric
    namespace (every key declared once in ``repro.obs.names`` — the old
    hand-maintained literal dict could drift against the router's). The
    draft_*/spec_steps values are mirrors of on-device counters, refreshed
    at sync() — they ride the existing once-per-chunk host transfer."""
    if registry is None:
        registry = MetricsRegistry()  # private, always-on: stats must count
    return registry.view(SERVE_ENGINE_METRICS, replica=replica)


def _shard_params(params, mesh):
    """Place a per-replica copy of the params on ``mesh`` (tensor-parallel
    along the rules of sharding/partition.py)."""
    specs = infer_param_specs(params, mesh_axes=dict(mesh.shape))
    shardings = jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), specs, is_leaf=lambda x: isinstance(x, P)
    )
    return jax.device_put(params, shardings)


class PrefillWorker:
    """Compute-bound half of the serving pair: bucketed prefill admission
    sealed into :class:`KVHandoff`s. Owns its own jitted program, rng chain
    and (paged layout) a staging pool bounding in-flight handoff pages."""

    def __init__(self, cfg, params, ecfg: EngineConfig, *, mesh=None,
                 stats: Optional[MutableMapping] = None, replica: int = 0):
        self.cfg = cfg
        self.ecfg = ecfg
        self.mesh = mesh
        self.replica = replica
        self.params = _shard_params(params, mesh) if mesh is not None else params
        self.layout = _engine_layout(cfg, ecfg)
        self.staging: Optional[KVPool] = KVPool(cfg, ecfg) if self.layout == "paged" else None
        self.stats = stats if stats is not None else _fresh_stats(replica=replica)
        self._prefill_jit = jax.jit(self._prefill_fn)
        self.reset()

    def reset(self) -> None:
        self._rng = jax.random.key(self.ecfg.seed + 1)  # decode chain owns seed
        if self.staging is not None:
            self.staging.reset()

    def bucket_len(self, prompt_len: int) -> int:
        return bucket_len(self.cfg, self.ecfg, prompt_len)

    def _prefill_fn(self, params, rng, tokens, true_lens):
        """ONE dispatch per (bucket, burst-size) combination: prefill N
        prompts, sample first tokens, seal attention KV into page units.
        N and the bucket length are compile-time constants per call."""
        cfg, e = self.cfg, self.ecfg
        n = tokens.shape[0]
        rng, key = jax.random.split(rng)
        st1 = init_lm_state(cfg, n, e.max_seq)
        logits, st1 = lm_prefill(params, cfg, {"tokens": tokens}, st1, last_index=true_lens - 1)
        toks0 = sample_tokens(logits[:, 0], key, e.temperature)  # (N,)
        if self.layout != "paged":
            return rng, st1, toks0
        ps = self.staging.page_size
        n_alloc = self.staging.required_pages(tokens.shape[1])
        sealed: Dict[str, Any] = {}
        for i, (mixer, _) in enumerate(group_pattern(cfg)):
            key_i = f"p{i}"
            if mixer != "attn":
                sealed[key_i] = st1[key_i]  # recurrent carry: dense rows
                continue
            sub = {}
            for pages_name, dense_name in (("k_pages", "k"), ("v_pages", "v")):
                one = st1[key_i][dense_name]  # (G, N, cl, KH, hd)
                g_, _, cl_, kh_, hd_ = one.shape
                pad = (-cl_) % ps
                if pad:
                    one = jnp.pad(one, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                # re-view the bucketed prefill as page units and keep only the
                # pages it actually filled — the sealed shape the adopting
                # pool scatters verbatim
                sub[pages_name] = one.reshape(g_, n, -1, ps, kh_, hd_)[:, :, :n_alloc]
            sealed[key_i] = sub
        return rng, sealed, toks0

    def prefill_group(self, group) -> KVHandoff:
        """Prefill one same-bucket group of ``(tokens, budget)`` pairs in a
        single dispatch and seal it for handoff. The caller (admit_many or a
        router) is responsible for power-of-two group sizing so the compiled
        program set stays O(log max_slots) per bucket."""
        n = len(group)
        lb = self.bucket_len(max(len(t) for t, _ in group))
        padded = np.zeros((n, lb), np.int32)
        lens = np.zeros((n,), np.int32)
        buds = np.zeros((n,), np.int32)
        for j, (tokens, budget) in enumerate(group):
            padded[j, : len(tokens)] = tokens
            lens[j], buds[j] = len(tokens), budget
        staging_id, n_alloc = -1, 0
        if self.staging is not None:
            # backpressure: the staging pool caps how many sealed-but-not-
            # adopted pages can be in flight; adopt() donates them back. The
            # reservation id comes from the pool's own staging counter so
            # reset() can account (and reclaim) in-flight handoffs.
            n_alloc = self.staging.required_pages(lb)
            staging_id, _ = self.staging.stage(n * n_alloc)
        with obs.span("serve.prefill", replica=self.replica, n=n, bucket=lb):
            self._rng, sealed, toks0 = self._prefill_jit(
                self.params, self._rng, jnp.asarray(padded), jnp.asarray(lens)
            )
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += n * lb
        return KVHandoff(
            sealed=sealed, first_tok=toks0, true_lens=lens, budgets=buds,
            n_alloc=n_alloc, staging_id=staging_id, source=self, tokens=padded,
        )

    def release(self, handoff: KVHandoff) -> None:
        """Donate a handoff's staging reservation back (the adopting worker
        has issued its copy of the sealed pages)."""
        if self.staging is not None and handoff.staging_id >= 0:
            self.staging.donate(handoff.staging_id)


class DecodeWorker:
    """Bandwidth-bound half of the serving pair: owns the slots, the KV pool
    and the chunked decode program; ingests sealed prefills via ``adopt``.

    Two opt-in accelerations live here because they need the pool and the
    slot state: the **radix prefix cache** (``ecfg.prefix_cache`` — hot
    admissions splice resident pages and prefill only the tail, via
    :meth:`admit_spliced`; spliced admissions must run on THIS worker, not
    the prefill worker, because the matched pages are resident in THIS pool)
    and **speculative decoding** (``ecfg.spec_k`` + a ``drafter`` — the
    chunk program drafts/verifies through :class:`repro.serve.spec_decode.
    SpecDecoder`)."""

    def __init__(self, cfg, params, ecfg: EngineConfig, *, mesh=None,
                 stats: Optional[MutableMapping] = None, drafter=None, replica: int = 0):
        self.cfg = cfg
        self.ecfg = ecfg
        self.mesh = mesh
        self.replica = replica
        self.params = _shard_params(params, mesh) if mesh is not None else params
        self.layout = _engine_layout(cfg, ecfg)
        self.pool: Optional[KVPool] = KVPool(cfg, ecfg) if self.layout == "paged" else None
        self.stats = stats if stats is not None else _fresh_stats(replica=replica)
        self.free_slots: List[int] = list(range(ecfg.max_slots))
        self._state: Optional[DecodeState] = None
        # host-side per-slot metadata for page planning: (true_len, budget)
        # and a conservative position estimate (reconciled downward at sync)
        self._meta: Dict[int, Tuple[int, int]] = {}
        self._pos_est: Dict[int, int] = {}
        # pages a slot borrowed from the prefix cache instead of allocating
        # (its billed load is discounted by exactly this many pages)
        self._spliced: Dict[int, int] = {}
        self.prefix = None
        if ecfg.prefix_cache:
            if self.layout != "paged":
                raise ValueError(
                    f"{cfg.name}: prefix_cache requires the paged layout, but this "
                    "arch has no attention KV to page (it degrades to dense)"
                )
            _require_extend_capable(cfg, ecfg, "prefix_cache")
            from repro.serve.prefix_cache import PrefixCache

            self.prefix = PrefixCache(self.pool)
        self._spec = None
        if ecfg.spec_k > 0:
            if drafter is None:
                raise ValueError(
                    "spec_k > 0 but no drafter: pass drafter=(cfg, params) — any "
                    "registry config with attention-only mixers (e.g. a reduced "
                    "smollm-135m) can draft"
                )
            if self.layout != "paged":
                raise ValueError(
                    f"{cfg.name}: spec_decode requires the paged layout — the "
                    "batched verify is an lm_extend over the page-table view"
                )
            _require_extend_capable(cfg, ecfg, "spec_decode")
            from repro.serve.spec_decode import SpecDecoder

            self._spec = SpecDecoder(self, drafter[0], drafter[1], ecfg.spec_k)
        elif drafter is not None:
            raise ValueError("drafter given but spec_k == 0: set spec_k to enable it")
        # evicted slots whose table rows still point at returned pages; their
        # ride-along writes must be re-aimed at the scratch page before the
        # next chunk (unless adoption rewrites the row first)
        self._adopt_jit = jax.jit(self._adopt_fn)
        self._splice_jit = jax.jit(self._splice_fn)
        self._cow_jit = jax.jit(self._cow_fn)
        self._stale_slots: set = set()
        donate = () if jax.default_backend() == "cpu" else (1,)
        self._chunk_jit = jax.jit(self._chunk_fn, donate_argnums=donate)
        self.reset()

    # -- device programs ----------------------------------------------------

    def _splice_dense(self, kv, st1, slots, n: int):
        """Per-row dense splice: each prefilled row lands on its slot's batch
        index in every state leaf. n <= max_slots: unrolled."""
        for i in range(n):
            kv = jax.tree_util.tree_map(
                lambda big, one: jax.lax.dynamic_update_slice(
                    big,
                    jax.lax.dynamic_slice_in_dim(one, i, 1, axis=1).astype(big.dtype),
                    (0, slots[i]) + (0,) * (big.ndim - 2),
                ),
                kv,
                st1,
            )
        return kv

    def _adopt_fn(self, ds: DecodeState, sealed, toks0, slots, true_lens, budgets,
                  table_rows, page_ids):
        """Ingest one sealed burst: PURE data movement (no model forward).
        Paged: sealed page units scatter into this worker's pool buffers at
        the ids its pool assigned (one scatter per leaf for the whole burst —
        page ids are disjoint across rows by the allocator invariant, so the
        (N, n_alloc) index array never collides); recurrent mixer states stay
        per-slot dense. Dense: per-row dynamic-update splice. Either way the
        slot bookkeeping vectors are rewritten for the adopted rows."""
        n = toks0.shape[0]
        if self.layout == "paged":
            kv = dict(ds.kv)
            for i, (mixer, _) in enumerate(group_pattern(self.cfg)):
                key = f"p{i}"
                if mixer != "attn":
                    kv[key] = self._splice_dense(kv[key], sealed[key], slots, n)
                    continue
                sub = dict(kv[key])
                for pages_name in ("k_pages", "v_pages"):
                    big = sub[pages_name]  # (G, P, ps, KH, hd)
                    sub[pages_name] = big.at[:, page_ids].set(
                        sealed[key][pages_name].astype(big.dtype)
                    )
                kv[key] = sub
            page_table = ds.page_table.at[slots].set(table_rows)
        else:
            kv = self._splice_dense(ds.kv, sealed, slots, n)
            page_table = ds.page_table
        return DecodeState(
            kv=kv,
            last_tok=ds.last_tok.at[slots, 0].set(toks0),
            pos=ds.pos.at[slots].set(true_lens),
            active=ds.active.at[slots].set(budgets > 1),
            out=ds.out.at[slots].set(0).at[slots, 0].set(toks0),
            n_out=ds.n_out.at[slots].set(1),
            budget=ds.budget.at[slots].set(budgets),
            rng=ds.rng,
            page_table=page_table,
        )

    def _attn_page_map(self, kv, fn):
        """Apply ``fn`` to every attention page-pool leaf of a kv pytree."""
        kv = dict(kv)
        for i, (mixer, _) in enumerate(group_pattern(self.cfg)):
            if mixer != "attn":
                continue
            key = f"p{i}"
            sub = dict(kv[key])
            for name in ("k_pages", "v_pages"):
                sub[name] = fn(sub[name])
            kv[key] = sub
        return kv

    def _cow_fn(self, ds: DecodeState, src, dst):
        """Copy-on-write device half: duplicate pages ``src`` (M,) into
        ``dst`` (M,) on every attention leaf ((G, P, page, KH, hd) — page dim
        is axis 1). The host half (KVPool.cow) already swapped the table
        entry; ``src == dst == scratch`` rows are harmless self-copies."""
        kv = self._attn_page_map(ds.kv, lambda big: big.at[:, dst].set(big[:, src]))
        return ds._replace(kv=kv)

    def _splice_fn(self, params, ds: DecodeState, tokens, slot, start, last_idx,
                   budget, true_len, table_row, cow_src, cow_dst):
        """One hot-prefix admission in ONE dispatch: the copy-on-write page
        duplicate (scratch→scratch when none is needed), the slot's new
        page-table row (spliced prefix pages + fresh tail pages), the tail
        extend (only the tokens the radix match did NOT cover — this is the
        whole point: a hot admission prefills ``tokens.shape[1]`` positions
        instead of the full prompt), first-token sampling from the true last
        prompt position, and the slot bookkeeping rewrite."""
        e = self.ecfg
        kv = self._attn_page_map(
            ds.kv, lambda big: big.at[:, cow_dst].set(big[:, cow_src])
        )
        page_table = ds.page_table.at[slot].set(table_row)
        logits, kv = lm_extend(
            params, self.cfg, tokens, kv, jnp.reshape(start, (1,)), table_row[None, :]
        )
        rng, key = jax.random.split(ds.rng)
        tok0 = sample_tokens(logits[:, last_idx], key, e.temperature)  # (1,)
        return DecodeState(
            kv=kv,
            last_tok=ds.last_tok.at[slot, 0].set(tok0[0]),
            pos=ds.pos.at[slot].set(true_len),
            active=ds.active.at[slot].set(budget > 1),
            out=ds.out.at[slot].set(0).at[slot, 0].set(tok0[0]),
            n_out=ds.n_out.at[slot].set(1),
            budget=ds.budget.at[slot].set(budget),
            rng=rng,
            page_table=page_table,
        )

    def _chunk_fn(self, params, ds: DecodeState):
        cfg, e = self.cfg, self.ecfg
        rows = jnp.arange(e.max_slots, dtype=jnp.int32)
        paged = self.layout == "paged"

        def cond(carry):
            i, s = carry
            return (i < e.decode_chunk) & jnp.any(s.active)

        def body(carry):
            i, s = carry
            logits, kv = lm_decode(
                params, cfg, s.last_tok, s.kv, s.pos,
                page_table=s.page_table if paged else None,
            )
            rng, ks = jax.random.split(s.rng)
            nxt = sample_tokens(logits[:, -1], ks, e.temperature)
            write = s.active & (s.n_out < e.max_new)
            idx = jnp.minimum(s.n_out, e.max_new - 1)
            out = s.out.at[rows, idx].set(jnp.where(write, nxt, s.out[rows, idx]))
            n_out = s.n_out + write.astype(jnp.int32)
            finished = n_out >= s.budget
            if e.eos_token >= 0:
                finished |= (nxt == e.eos_token) & s.active
            return i + 1, DecodeState(
                kv=kv,
                last_tok=jnp.where(s.active[:, None], nxt[:, None], s.last_tok),
                pos=s.pos + s.active.astype(jnp.int32),
                active=s.active & ~finished,
                out=out,
                n_out=n_out,
                budget=s.budget,
                rng=rng,
                page_table=s.page_table,
            )

        _, ds = jax.lax.while_loop(cond, body, (jnp.zeros((), jnp.int32), ds))
        return ds

    # -- host API -----------------------------------------------------------

    def reset(self) -> None:
        """(Re)build the device state: all slots free, caches zeroed. Stats
        are NOT zeroed here — the shared dict belongs to the composition
        (ServeEngine.reset) or to the caller of a bare worker."""
        cfg, e = self.cfg, self.ecfg
        self.free_slots = list(range(e.max_slots))
        self._meta = {}
        self._pos_est = {}
        self._spliced = {}
        self._stale_slots = set()
        if self.prefix is not None:
            self.prefix.clear()  # refcounts are wiped by pool.reset() below
        if self._spec is not None:
            self._spec.reset()
        if self.pool is not None:
            self.pool.reset()
            # +1: the scratch page — the write target of idle slots' frozen
            # ride-along positions (never allocated, reads always masked)
            kv = init_lm_state(
                cfg, e.max_slots, e.max_seq,
                kv_pages=self.pool.n_pages + 1, kv_page_size=self.pool.page_size,
            )
            width = self.pool.pages_per_slot
            table0 = jnp.full((e.max_slots, width), self.pool.scratch_page, jnp.int32)
        else:
            kv = init_lm_state(cfg, e.max_slots, e.max_seq)
            width = 1
            table0 = jnp.zeros((e.max_slots, width), jnp.int32)
        state = DecodeState(
            kv=kv,
            last_tok=jnp.zeros((e.max_slots, 1), jnp.int32),
            pos=jnp.zeros((e.max_slots,), jnp.int32),
            active=jnp.zeros((e.max_slots,), bool),
            out=jnp.zeros((e.max_slots, e.max_new), jnp.int32),
            n_out=jnp.zeros((e.max_slots,), jnp.int32),
            budget=jnp.zeros((e.max_slots,), jnp.int32),
            rng=jax.random.key(e.seed),
            page_table=table0,
        )
        if self.mesh is not None:
            # shard the engine state over this worker's mesh slice (page
            # pools and caches along the heads axis; bookkeeping replicated)
            specs = shard_engine_state(state, mesh_axes=dict(self.mesh.shape))
            shardings = jax.tree_util.tree_map(
                lambda spec: NamedSharding(self.mesh, spec), specs,
                is_leaf=lambda x: isinstance(x, P),
            )
            state = jax.device_put(state, shardings)
        self._state = state

    def _lifetime_pages(self, prompt_len: int, budget: int) -> int:
        """A request's TOTAL page bill over its life: the bucketed prefill
        plus every decode position its budget can reach (ring-clamped)."""
        lb = bucket_len(self.cfg, self.ecfg, prompt_len)
        return self.pool.required_pages(max(lb, prompt_len + budget))

    def request_load(self, prompt_len: int, budget: int) -> int:
        """The admission-load unit a router bills for one request: lifetime
        pages in the paged layout, one slot otherwise."""
        if self.pool is None:
            return 1
        return self._lifetime_pages(prompt_len, budget)

    def billed_pages(self) -> int:
        """Resident load: lifetime page bill of every resident request
        (paged) or the resident count (dense). Spliced pages are DISCOUNTED —
        a request serving its prompt off shared prefix pages loads the pool
        (and the router's least-loaded comparison) only by the pages it
        privately grows into."""
        if self.pool is None:
            return self.ecfg.max_slots - len(self.free_slots)
        return sum(
            self._lifetime_pages(tl, b) - self._spliced.get(slot, 0)
            for slot, (tl, b) in self._meta.items()
        )

    def prefix_probe(self, tokens) -> int:
        """Resident full prefix pages for a prompt (0 without the cache) —
        read-only: no LRU touch, so capacity checks and router affinity
        probes never age the cache."""
        if self.prefix is None:
            return 0
        return self.prefix.probe(np.asarray(tokens, np.int32).reshape(-1))

    def _headroom(self) -> int:
        """Pages obtainable for growth right now: the free list plus every
        cache-only page eviction could reclaim on demand."""
        free = self.pool.free_pages
        if self.prefix is not None:
            free += self.prefix.reclaimable()
        return free

    def _committed_growth(self) -> int:
        """Pages resident requests may still demand: lifetime bill minus the
        pages already in their tables (attached prefix pages count — they
        never need re-allocating)."""
        return sum(
            max(self._lifetime_pages(tl, b) - len(self.pool.owned(slot)), 0)
            for slot, (tl, b) in self._meta.items()
        )

    def _make_room(self, n_pages: int) -> None:
        """Ensure ``n_pages`` are on the free list, evicting LRU cache-only
        pages if needed (their refcount drops to zero — truly orphaned)."""
        if self.prefix is not None and n_pages > self.pool.free_pages:
            self.prefix.make_room(n_pages - self.pool.free_pages)

    def can_ever_admit(self, prompt_len: int, budget: int) -> bool:
        """Whether an EMPTY instance of this worker could admit the request
        (its lifetime bill fits the whole pool). A router uses this to fail
        fast on requests no amount of draining can make admissible."""
        if self.pool is None:
            return True
        return self._lifetime_pages(prompt_len, budget) <= self.pool.n_pages

    def max_admissible(self, requests) -> int:
        """Largest prefix of ``requests`` ((tokens, budget) pairs) admissible
        RIGHT NOW: bounded by free slots and, in the paged layout, by pool
        capacity net of every RESIDENT request's remaining growth. Billing
        lifetimes (not just prefills — budgets are known at admission) means
        residents can always grow to their full budget: a scheduler that
        admits through this can never hit mid-decode pool exhaustion; a
        tight pool defers requests instead of crashing the run.

        With the prefix cache, capacity = free pages + reclaimable cache
        pages, and each candidate still bills its FULL lifetime: a spliced
        admission consumes ``lifetime - matched`` fresh pages but pins its
        ``matched`` pages un-reclaimable (and the r==0 boundary case trades
        one splice for one CoW page), so lifetime is the exact worst-case
        claim either way — the sharing win shows up in residents' committed
        growth (attached pages are already in their tables), not in an
        optimistic candidate discount."""
        n = min(len(requests), len(self.free_slots))
        if self.pool is None:
            return n
        free = self._headroom() - self._committed_growth()
        count = 0
        for tokens, budget in list(requests)[:n]:
            tokens = np.asarray(tokens, np.int32).reshape(-1)
            need = self._lifetime_pages(len(tokens), budget)
            if need > free:
                break
            free -= need
            count += 1
        return count

    def adopt(self, handoff: KVHandoff) -> List[int]:
        """Land one sealed burst on this worker's slots/pool. Atomic w.r.t.
        pool exhaustion: the whole burst's page bill is checked before a slot
        is popped or a page adopted, so a caller that catches the error has a
        clean worker and an intact handoff to retry elsewhere."""
        n = handoff.n
        if n > len(self.free_slots):
            raise RuntimeError(
                f"{n} adoptions but only {len(self.free_slots)} free slots"
            )
        if self.pool is not None:
            if handoff.n_alloc == 0:
                raise ValueError(
                    "dense handoff offered to a paged decode worker: the "
                    "prefill and decode halves of a pair must share kv_layout"
                )
            self._make_room(n * handoff.n_alloc)
            if n * handoff.n_alloc > self.pool.free_pages:
                raise RuntimeError(
                    f"KV pool cannot adopt this burst: its sealed prefills need "
                    f"{n * handoff.n_alloc} pages but only {self.pool.free_pages}/"
                    f"{self.pool.n_pages} are free (page_size={self.pool.page_size}). "
                    "Adopt fewer requests, raise --pool-pages, or lower --max-slots."
                )
        sealed, toks0 = handoff.sealed, handoff.first_tok
        if self.mesh is not None and getattr(handoff.source, "mesh", None) is not self.mesh:
            # cross-worker transport: the sealed buffers were produced on the
            # prefill worker's mesh slice — replicate them onto ours (the
            # ICI/DCN hop of a real disaggregated fleet)
            with obs.span("serve.handoff", replica=self.replica, n=n):
                rep = NamedSharding(self.mesh, P())
                sealed, toks0 = jax.device_put((sealed, toks0), rep)
        gslots = [self.free_slots.pop() for _ in range(n)]
        width = self.pool.pages_per_slot if self.pool is not None else 1
        table_rows = np.zeros((n, width), np.int32)
        page_ids = np.zeros((n, max(handoff.n_alloc, 1)), np.int32)
        for j, slot in enumerate(gslots):
            if self.pool is not None:
                page_ids[j] = self.pool.adopt(slot, handoff.n_alloc)
                table_rows[j] = self.pool.table_row(slot)
                self._meta[slot] = (int(handoff.true_lens[j]), int(handoff.budgets[j]))
                self._pos_est[slot] = int(handoff.true_lens[j])
                self._spliced[slot] = 0
                self._stale_slots.discard(slot)  # row fully rewritten
        self.stats["pages_allocated"] += n * max(handoff.n_alloc, 0)
        with obs.span("serve.adopt", replica=self.replica, n=n):
            self._state = self._adopt_jit(
                self._state,
                sealed,
                toks0,
                jnp.asarray(gslots, jnp.int32),
                jnp.asarray(handoff.true_lens),
                jnp.asarray(handoff.budgets),
                jnp.asarray(table_rows),
                jnp.asarray(page_ids),
            )
        handoff.source.release(handoff)
        if self._spec is not None:
            self._spec.on_admit(
                gslots,
                np.asarray(handoff.tokens),
                [int(t) for t in np.asarray(handoff.true_lens)],
            )
        self.stats["admitted"] += n
        self.stats["handoffs"] += 1
        return gslots

    def admit_spliced(self, tokens, budget: int) -> Optional[int]:
        """Hot-prefix admission: splice the longest resident radix run into a
        fresh slot's page table (``KVPool.attach``) and prefill ONLY the
        uncovered tail — the prompt's cached pages are never recomputed.
        Returns the slot id, or ``None`` when the cache holds no full page of
        this prompt (the caller falls back to the classic prefill path).

        Must run on THIS worker (never the prefill half of a disaggregated
        pair): the matched pages are resident in THIS pool's device buffers.

        Token parity with the cold path is the contract: the spliced pages
        hold bitwise the KV a full prefill of the same prompt would produce
        (same params, same positions), and the tail extend reproduces prefill
        semantics for the rest — so greedy outputs match the cold admission
        bitwise. The one boundary case is a prompt the cache covers ENTIRELY
        (tail length 0): the last prompt token's logits must be recomputed to
        sample the first output, and that replay re-writes one position in
        the final matched page — which other requests may share, and whose
        reduction order a different batch shape could perturb. The replay
        therefore ALWAYS goes through copy-on-write, never writes the shared
        page."""
        if self.prefix is None:
            return None
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        true_len = len(tokens)
        pages = self.prefix.match(tokens)
        if not pages:
            return None
        if not self.free_slots:
            raise RuntimeError("spliced admission with no free slot")
        e, ps = self.ecfg, self.pool.page_size
        m = len(pages)
        r = true_len - m * ps  # uncovered tail tokens
        fresh = self.pool.required_pages(true_len) - m + (1 if r == 0 else 0)
        self._make_room(fresh)
        if fresh > self.pool.free_pages:
            raise RuntimeError(
                f"KV pool cannot admit this spliced request: its tail needs "
                f"{fresh} fresh pages but only {self.pool.free_pages}/"
                f"{self.pool.n_pages} are free (page_size={ps}). Drain a "
                "request, raise --pool-pages, or lower --max-slots."
            )
        slot = self.free_slots.pop()
        self.pool.attach(slot, pages)
        cow_src = cow_dst = self.pool.scratch_page  # harmless self-copy
        if r > 0:
            self.pool.alloc(slot, self.pool.required_pages(true_len))
            tb = min(-(-r // e.prefill_bucket) * e.prefill_bucket, e.max_seq)
            start, last_idx = m * ps, r - 1
            tail = np.zeros((1, tb), np.int32)
            tail[0, :r] = tokens[m * ps :]
        else:
            # fully-covered prompt: CoW the final matched page, then replay
            # the last prompt token into the private copy to recover its
            # logits (the cache stores KV, not logits)
            cow_src, cow_dst = self.pool.cow(slot, m - 1)
            if cow_src != cow_dst:
                self.stats["cow_copies"] += 1
            tb, start, last_idx = 1, true_len - 1, 0
            tail = tokens[None, -1:].copy()
        table_row = self.pool.table_row(slot)  # AFTER cow: private ids only
        # scalars ride as traced device values so the compiled program is
        # keyed on the tail bucket alone, not on slot/length combinations
        with obs.span("serve.splice", replica=self.replica, matched=m, tail=tb):
            self._state = self._splice_jit(
                self.params,
                self._state,
                jnp.asarray(tail),
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(start, jnp.int32),
                jnp.asarray(last_idx, jnp.int32),
                jnp.asarray(budget, jnp.int32),
                jnp.asarray(true_len, jnp.int32),
                jnp.asarray(table_row),
                jnp.asarray(cow_src, jnp.int32),
                jnp.asarray(cow_dst, jnp.int32),
            )
        self._meta[slot] = (true_len, budget)
        self._pos_est[slot] = true_len
        self._spliced[slot] = m
        self._stale_slots.discard(slot)  # row fully rewritten by the splice
        if self._spec is not None:
            # the drafter shares no pages — it prefills the FULL prompt into
            # its own dense cache (cheap: the drafter is small by design)
            lb = bucket_len(self.cfg, e, true_len)
            padded = np.zeros((1, lb), np.int32)
            padded[0, :true_len] = tokens
            self._spec.on_admit([slot], padded, [true_len])
        self.stats["admitted"] += 1
        self.stats["prefix_hits"] += 1
        self.stats["spliced_admissions"] += 1
        self.stats["spliced_pages"] += m
        self.stats["prefill_tokens"] += tb
        self.stats["pages_allocated"] += fresh
        return slot

    def _ensure_chunk_pages(self) -> None:
        """Grow resident slots' page tables to cover the positions the next
        chunk can write. The estimate only moves DOWN at sync reconciliation,
        so back-to-back chunks without a sync stay safe (a page is appended
        at worst one chunk early, never late — late would silently write
        through a padding table entry)."""
        e = self.ecfg
        # a speculative chunk's verify extend can write up to steps*(k+1)
        # positions (plus rejected-draft garbage the NEXT verify overwrites —
        # writes past the planned coverage redirect to the scratch page)
        horizon = self._spec.horizon if self._spec is not None else e.decode_chunk
        # phase 1 — PLAN, no mutation: the chunk's total page bill, so
        # exhaustion raises with the engine untouched (stale set intact,
        # pool unallocated — a caller that catches can drain and retry;
        # committing anything partially here would either forget a stale
        # row, re-opening the cross-slot clobber, or leave a slot owning
        # pages its device table never maps)
        growth: List[Tuple[int, int, int]] = []  # (slot, have, need)
        cows: List[Tuple[int, int]] = []  # (slot, page idx) to copy-on-write
        total_new = 0
        for slot, (true_len, budget) in self._meta.items():
            est = self._pos_est[slot]
            end = min(est + horizon, true_len + budget)
            need = self.pool.required_pages(end)
            owned = self.pool.owned(slot)
            if need > len(owned):
                growth.append((slot, len(owned), need))
                total_new += need - len(owned)
            # a write crossing into a page another slot (or the prefix cache)
            # still references must copy first — sharing is read-only
            ps = self.pool.page_size
            for idx in range(est // ps, min(-(-end // ps), len(owned))):
                if self.pool.refcount(owned[idx]) > 1:
                    cows.append((slot, idx))
                    total_new += 1
        self._make_room(total_new)
        if total_new > self.pool.free_pages:
            raise RuntimeError(
                f"KV pool exhausted mid-decode: growing {len(growth)} slot(s) for "
                f"the next chunk needs {total_new} pages but only "
                f"{self.pool.free_pages}/{self.pool.n_pages} are free "
                f"(page_size={self.pool.page_size}). Raise --pool-pages or admit "
                "fewer/shorter requests; the engine state is unchanged."
            )
        # phase 2 — COMMIT: allocations cannot fail now. Evicted slots'
        # stale rows are re-aimed at the scratch page in the same table
        # update (their frozen ride-along writes must not land on pages the
        # pool may reissue); the stale set is cleared only after the device
        # table actually carries the re-aim.
        upd_rows: List[int] = []
        upd_cols: List[int] = []
        upd_vals: List[int] = []
        for slot in sorted(self._stale_slots):
            for k in range(self.pool.pages_per_slot):
                upd_rows.append(slot)
                upd_cols.append(k)
                upd_vals.append(self.pool.scratch_page)
            self.stats["table_resets"] += 1
        for slot, have, need in growth:
            pages = self.pool.alloc(slot, need)
            for k in range(have, need):
                upd_rows.append(slot)
                upd_cols.append(k)
                upd_vals.append(pages[k])
            self.stats["page_appends"] += need - have
            self.stats["pages_allocated"] += need - have
        cow_src: List[int] = []
        cow_dst: List[int] = []
        for slot, idx in cows:
            src, dst = self.pool.cow(slot, idx)
            if src == dst:
                continue  # became private since the plan (same-batch dedup)
            cow_src.append(src)
            cow_dst.append(dst)
            upd_rows.append(slot)
            upd_cols.append(idx)
            upd_vals.append(dst)
            self.stats["cow_copies"] += 1
            self.stats["pages_allocated"] += 1
        for slot, (true_len, budget) in self._meta.items():
            self._pos_est[slot] = min(
                self._pos_est[slot] + horizon, true_len + budget - 1
            )
        if upd_rows:
            self._state = self._state._replace(
                page_table=self._state.page_table.at[
                    jnp.asarray(upd_rows, jnp.int32), jnp.asarray(upd_cols, jnp.int32)
                ].set(jnp.asarray(upd_vals, jnp.int32))
            )
        if cow_src:
            self._state = self._cow_jit(
                self._state, jnp.asarray(cow_src, jnp.int32), jnp.asarray(cow_dst, jnp.int32)
            )
        self._stale_slots.clear()

    def decode_chunk(self) -> None:
        """Up to ``decode_chunk`` batched decode steps in ONE dispatch (or,
        with speculative decoding on, the draft/verify chunk program). The
        span brackets dispatch submission only — no sync is forced, so the
        O(1)-host-syncs-per-chunk contract holds with tracing on."""
        with obs.span("serve.decode_chunk", replica=self.replica):
            if self.pool is not None:
                self._ensure_chunk_pages()
            if self._spec is not None:
                self._spec.chunk()
            else:
                self._state = self._chunk_jit(self.params, self._state)
        self.stats["decode_chunks"] += 1

    def sync(self):
        """The once-per-chunk host sync: (active, n_out) as numpy, fetched
        in a single device-to-host transfer. Also reconciles the paged
        layout's conservative per-slot position estimates to the truth, and
        (spec mode) refreshes the draft counters' host mirrors — the
        counters ride the SAME transfer, costing no extra sync."""
        with obs.span("serve.sync", replica=self.replica):
            if self._spec is not None:
                active, n_out = self._spec.sync()
            else:
                active, n_out = jax.device_get((self._state.active, self._state.n_out))
        self.stats["host_syncs"] += 1
        if self.pool is not None:
            for slot, (true_len, _) in self._meta.items():
                self._pos_est[slot] = true_len + int(n_out[slot]) - 1
        return active, n_out

    def publish_gauges(self) -> None:
        """Push the pool/prefix occupancy gauges into the stats registry —
        called at snapshot/dump time (occupancy is a point-in-time value;
        sampling it per chunk would be noise, not signal)."""
        if not isinstance(self.stats, StatsView):
            return
        reg, labels = self.stats.registry, self.stats.labels
        if self.pool is not None:
            reg.set_gauge(KV_GAUGES["free_pages"], self.pool.free_pages, **labels)
            reg.set_gauge(KV_GAUGES["pages_in_use"], self.pool.pages_in_use, **labels)
            reg.set_gauge(KV_GAUGES["capacity_pages"], self.pool.n_pages, **labels)
        if self.prefix is not None:
            reg.set_gauge(
                KV_GAUGES["reclaimable_pages"], self.prefix.reclaimable(), **labels
            )

    def fetch(self, slot: int, n_out: int) -> np.ndarray:
        """Copy a finished slot's generated tokens to host and free the slot
        (returning its truly-orphaned pages to the pool in the paged layout —
        pages the prefix cache pins stay resident for future splices)."""
        toks = np.asarray(self._state.out[slot])[:n_out]
        self.free_slots.append(slot)
        if self.pool is not None:
            self.pool.free_slot(slot)
            self._meta.pop(slot, None)
            self._pos_est.pop(slot, None)
            self._spliced.pop(slot, None)
            self._stale_slots.add(slot)
        self.stats["evicted"] += 1
        return toks


class ServeEngine:
    """One fleet replica: a :class:`PrefillWorker` and a
    :class:`DecodeWorker` composed behind the classic engine API that
    :class:`repro.serve.scheduler.FleetRouter` (and its N=1 case,
    ``ContinuousScheduler``) drives from the request queue.

    Colocated by default: both workers share the params and (if given) the
    same mesh slice. With ``ecfg.disagg`` (or distinct ``prefill_mesh``/
    ``mesh``) the pair is disaggregated — prefill seals pages on its slice,
    adoption scatters them into the decode worker's pool, the classic
    production split of compute-bound admission from bandwidth-bound decode.
    Either way admission runs the SAME two programs, so the colocated engine
    is the disaggregated pair's parity oracle by construction."""

    def __init__(self, cfg, params, ecfg: EngineConfig, *, mesh=None, prefill_mesh=None,
                 drafter=None, registry: Optional[MetricsRegistry] = None,
                 replica: int = 0):
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only: nothing to decode")
        if cfg.frontend == "vision":
            raise ValueError(
                f"{cfg.name} needs per-request vision prefix embeddings, which "
                "the slot engine does not thread through admission yet; serve "
                "vlm archs with the static batch path"
            )
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.layout = _engine_layout(cfg, ecfg)
        if ecfg.disagg and self.layout != "paged":
            raise ValueError(
                f"{cfg.name} has no attention layers: its serving state degrades "
                "to the dense layout, which has no page units to hand off — a "
                "disaggregated prefill/decode pair is paged-only. Drop --disagg."
            )
        self.replica = replica
        self.stats: StatsView = _fresh_stats(registry, replica)
        self.prefill = PrefillWorker(
            cfg, params, ecfg, mesh=prefill_mesh if prefill_mesh is not None else mesh,
            stats=self.stats, replica=replica,
        )
        self.decode = DecodeWorker(
            cfg, params, ecfg, mesh=mesh, stats=self.stats, drafter=drafter,
            replica=replica,
        )

    # -- delegation (the device state lives on the workers) -----------------

    @property
    def pool(self) -> Optional[KVPool]:
        return self.decode.pool

    @property
    def prefix(self):
        """The decode worker's radix prefix cache (None when disabled)."""
        return self.decode.prefix

    @property
    def free_slots(self) -> List[int]:
        return self.decode.free_slots

    @property
    def _state(self) -> Optional[DecodeState]:
        return self.decode._state

    @property
    def _meta(self) -> Dict[int, Tuple[int, int]]:
        return self.decode._meta

    @property
    def _stale_slots(self) -> set:
        return self.decode._stale_slots

    def reset(self) -> None:
        """(Re)build both workers' device state and zero the shared stats
        (so a warm-up run never contaminates timed counters)."""
        for k in list(self.stats):
            self.stats[k] = 0
        self.prefill.reset()
        self.decode.reset()

    def bucket_len(self, prompt_len: int) -> int:
        return bucket_len(self.cfg, self.ecfg, prompt_len)

    def request_load(self, prompt_len: int, budget: int) -> int:
        return self.decode.request_load(prompt_len, budget)

    def billed_pages(self) -> int:
        return self.decode.billed_pages()

    def can_ever_admit(self, prompt_len: int, budget: int) -> bool:
        return self.decode.can_ever_admit(prompt_len, budget)

    def max_admissible(self, requests) -> int:
        return self.decode.max_admissible(requests)

    def prefix_hit_pages(self, tokens) -> int:
        """Resident full prefix pages for a prompt (0 without the cache) —
        the router's prefix-affinity signal. Read-only: never ages the LRU."""
        return self.decode.prefix_probe(tokens)

    def admit(self, tokens: np.ndarray, max_new_tokens: int) -> int:
        """Prefill one prompt (1-D int32) into a free slot; returns its id."""
        return self.admit_many([(tokens, max_new_tokens)])[0]

    def admit_many(self, requests) -> List[int]:
        """Admit several prompts; returns their slots, input-aligned.

        Prompts sharing a bucket length prefill together: each group is
        split into power-of-two admission batches (4+2+1…) so the set of
        compiled (bucket, N) programs stays O(log max_slots) per bucket
        instead of one per burst size — a freed-slot refill after warm-up
        never hits the compiler. Each group is ONE prefill dispatch sealed
        into a KVHandoff and ONE adoption scatter on the decode worker."""
        e = self.ecfg
        prepped = []
        for tokens, max_new_tokens in requests:
            tokens = np.asarray(tokens, np.int32).reshape(-1)
            if len(tokens) + max_new_tokens > e.max_seq:
                raise ValueError(
                    f"prompt ({len(tokens)}) + budget ({max_new_tokens}) exceeds max_seq={e.max_seq}"
                )
            if not 1 <= max_new_tokens <= e.max_new:
                raise ValueError(
                    f"max_new_tokens must be in [1, {e.max_new}], got {max_new_tokens}"
                )
            prepped.append((tokens, max_new_tokens))
        if len(prepped) > len(self.free_slots):
            raise RuntimeError(
                f"{len(prepped)} admissions but only {len(self.free_slots)} free slots"
            )
        prefix = self.decode.prefix
        hot: List[int] = []
        if prefix is not None:
            # probe (read-only) BEFORE any admission: intra-burst duplicates
            # do not share with each other — sharing materializes across
            # scheduler ticks, once the first copy's pages are indexed below
            hot = [
                i for i, (tokens, _) in enumerate(prepped)
                if self.decode.prefix_probe(tokens) > 0
            ]
        if self.pool is not None:
            # admission is ATOMIC w.r.t. pool exhaustion: check the whole
            # burst's page bill before prefilling, popping a slot or adopting
            # a page, so a caller that catches the error has a clean engine
            # (no half-admitted rows, no leaked slots/pages) and can retry
            # with a smaller burst. Hot requests bill only their uncovered
            # tail (+1 for the fully-covered replay's CoW page) — evicting a
            # matched page to make room frees exactly the page its splice
            # would have saved, so the bill stays sufficient either way.
            ps = self.pool.page_size
            need = 0
            hot_idx = set(hot)
            for i, (tokens, _) in enumerate(prepped):
                if i in hot_idx:
                    m = self.decode.prefix_probe(tokens)
                    r = len(tokens) - m * ps
                    need += self.pool.required_pages(len(tokens)) - m + (1 if r == 0 else 0)
                else:
                    need += self.pool.required_pages(self.bucket_len(len(tokens)))
            self.decode._make_room(need)
            if need > self.pool.free_pages:
                raise RuntimeError(
                    f"KV pool cannot admit this burst: its bucketed prefills need "
                    f"{need} pages but only {self.pool.free_pages}/{self.pool.n_pages} "
                    f"are free (page_size={self.pool.page_size}). Admit fewer "
                    "requests, raise --pool-pages, or lower --max-slots."
                )
        slots = [0] * len(prepped)
        cold: List[int] = []
        hot_set = set(hot)
        for i in range(len(prepped)):
            if i not in hot_set:
                cold.append(i)
                continue
            tokens, budget = prepped[i]
            slot = self.decode.admit_spliced(tokens, budget)
            if slot is None:  # match evicted since the probe: classic path
                cold.append(i)
            else:
                slots[i] = slot
        by_bucket: Dict[int, List[int]] = {}
        for i in cold:
            by_bucket.setdefault(self.bucket_len(len(prepped[i][0])), []).append(i)
        for lb, idxs in by_bucket.items():
            while idxs:
                n = 1 << (len(idxs).bit_length() - 1)  # largest pow2 <= len
                group, idxs = idxs[:n], idxs[n:]
                handoff = self.prefill.prefill_group([prepped[i] for i in group])
                gslots = self.decode.adopt(handoff)
                for j, i in enumerate(group):
                    slots[i] = gslots[j]
        if prefix is not None:
            # index every admitted prompt's full pages — spliced prompts map
            # their chunks to the very pages they attached, so only fresh
            # tails add nodes; the NEXT burst with these prefixes splices
            for i, (tokens, _) in enumerate(prepped):
                prefix.insert(tokens, self.pool.owned(slots[i]))
        return slots

    def warmup(self, prompt: np.ndarray, budget: int = 2) -> None:
        """Compile every admission program a serving run can hit — one per
        power-of-two burst size up to ``max_slots`` for ``prompt``'s bucket —
        plus the decode-chunk program, then reset. Without this, the first
        burst of a previously-unseen size pays XLA compilation mid-serving."""
        budget = min(budget, self.ecfg.max_new)
        n = 1
        while n <= self.ecfg.max_slots:
            self.reset()
            reqs = [(prompt, budget)] * n
            if self.max_admissible(reqs) < n:
                break  # a tight pool caps the burst; larger sizes can't fit either
            self.admit_many(reqs)
            self.decode_chunk()
            self.sync()
            n *= 2
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if (
            self.decode.prefix is not None
            and len(prompt) >= self.ecfg.page_size
            and self.ecfg.max_slots >= 2
            and len(prompt) + 1 + budget <= self.ecfg.max_seq
        ):
            # compile both splice programs: admit cold (seeds the cache), then
            # re-admit the same prompt (fully-covered replay, tail bucket 1)
            # and a one-token-longer prompt (tail extend, one prefill bucket)
            self.reset()
            self.admit(prompt, budget)
            if self.max_admissible([(prompt, budget)]) >= 1:
                self.admit(prompt, budget)
            longer = np.concatenate([prompt, prompt[-1:]])
            if self.max_admissible([(longer, budget)]) >= 1:
                self.admit(longer, budget)
            self.decode_chunk()
            self.sync()
        self.reset()

    def decode_chunk(self) -> None:
        self.decode.decode_chunk()

    def sync(self):
        return self.decode.sync()

    def fetch(self, slot: int, n_out: int) -> np.ndarray:
        return self.decode.fetch(slot, n_out)

    def publish_gauges(self) -> None:
        """Push pool/prefix occupancy gauges into the stats registry."""
        self.decode.publish_gauges()

"""Paged KV-cache block pool for the continuous-batching engine.

The dense engine cache is a per-slot rectangle: every slot owns
``cache_len = min(window, max_seq) or max_seq`` KV positions whether it is
serving a 2k-token request or an 8-token one — HBM is ``slots × max_len``
at rest. The pool replaces that rectangle with fixed-size **pages**:

  * the device buffers are ``(pool_pages, page_size, KH, hd)`` per attention
    layer (stacked over scan groups) — HBM scales with *allocated pages*,
    i.e. live tokens, not slot capacity;
  * each slot's logical cache is its **page table** row: logical index ``j``
    lives at ``(page_table[slot, j // page_size], j % page_size)``. For
    sliding-window layers the logical space is the same ring the dense cache
    uses, so the two layouts are token-for-token interchangeable;
  * this class is the HOST-side allocator: a free list plus per-slot
    ownership. Admission allocates the pages the bucketed prefill fills,
    :meth:`ServeEngine.decode_chunk` appends pages as positions cross page
    boundaries (at chunk granularity — the device program never touches the
    free list), and eviction returns a slot's pages.

Pages are **refcounted** so the radix prefix cache (``serve/prefix_cache.py``)
can share one physical page between several slots (and keep it resident after
every owner drains): ``alloc`` hands out fresh pages at refcount 1,
``attach`` splices already-allocated pages into another slot's table
(incref), ``incref``/``decref`` let the prefix cache pin pages with no slot
owner at all, and ``free_slot`` only returns truly-orphaned pages (refcount
hitting 0) to the free list. A decode write that would land in a shared page
goes through ``cow`` — a fresh private copy — never through the shared page.

Invariants (pinned by ``tests/test_kv_pool.py``'s randomized property test):
free + allocated always partitions ``range(n_pages)``; a page appears at most
once in any one slot's table; a page's refcount equals the number of slot
tables it appears in plus its prefix-cache pins; no page is freed while its
refcount is positive; ``alloc`` past capacity raises instead of silently
reusing.

Unallocated/stale page-table entries point at the **scratch page** — one
sacrificial page past the pool that is never handed out. It exists because
idle slots keep rewriting their frozen position as they ride along in the
batched decode: pointing them anywhere allocatable would clobber a live
slot's KV the moment their old pages were reissued.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.models.attention import cache_len


class KVPool:
    """Host-side page allocator; the page buffers themselves live in the
    engine's device state and are addressed by the ids handed out here."""

    def __init__(self, cfg, ecfg):
        self.page_size = ecfg.page_size
        self.cache_len = cache_len(cfg, ecfg.max_seq)
        # table width: pages needed to cover one slot's full logical cache
        self.pages_per_slot = -(-self.cache_len // self.page_size)
        self.n_pages = ecfg.pool_pages or ecfg.max_slots * self.pages_per_slot
        # fail-fast floor, billed in PAGES against the MODEL's cache length:
        # a minimal (bucket_min-token) admission occupies whole pages, but
        # never more than the slot's full ring — so tight SWA pools that a
        # token-level or window-blind bound would spuriously reject pass.
        # pages_min >= 1, so this also guarantees one page per slot.
        bucket_min = min(ecfg.prefill_bucket, ecfg.max_seq)
        pages_min = min(-(-bucket_min // self.page_size), self.pages_per_slot)
        if self.n_pages < ecfg.max_slots * pages_min:
            raise ValueError(
                f"pool_pages={self.n_pages} cannot back max_slots={ecfg.max_slots} "
                f"minimal admissions of {pages_min} page(s) each "
                f"(bucket_min={bucket_min} tokens, page_size={self.page_size}, "
                f"cache_len={self.cache_len}) — a full admission burst would "
                "exhaust the pool at prefill. Raise pool_pages or lower "
                "max_slots/prefill_bucket."
            )
        # INACTIVE slots still ride along in the batched decode, rewriting
        # their frozen position every step (the dense layout absorbs that in
        # the slot's own row). Their page-table rows must therefore never
        # point at allocatable pages: one sacrificial page past the pool is
        # the write target for every idle/evicted slot. It is never handed
        # out, so a stale row can clobber nothing.
        self.scratch_page = self.n_pages
        self._free: List[int] = []
        self._owned: Dict[int, List[int]] = {}
        self._ref: Dict[int, int] = {}
        self._staged: set = set()
        self._next_sid = 0
        self.reset()

    # -- bookkeeping ---------------------------------------------------------

    def reset(self) -> None:
        """Return the pool to its pristine state. Clears ownership, the free
        list, per-page refcounts AND the donate/adopt staging bookkeeping —
        a handoff staged before reset must not leak a reservation (or a stale
        refcount on a reissued page id) into the next run."""
        self._free = list(range(self.n_pages - 1, -1, -1))  # pop() hands out 0 first
        self._owned = {}
        self._ref = {}
        self._staged = set()
        # sid stays monotonic: a KVHandoff sealed before reset must never
        # collide with a reservation staged after it.

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Distinct allocated pages (a page shared by N tables counts once)."""
        return len(self._ref)

    @property
    def staged_ids(self) -> List[int]:
        """Staging reservations currently holding pages (handoff in flight)."""
        return sorted(self._staged)

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))

    def refcount(self, page: int) -> int:
        """0 for free pages; otherwise slot-table memberships + cache pins."""
        return self._ref.get(page, 0)

    def required_pages(self, length: int) -> int:
        """Pages covering ``length`` logical positions (ring-clamped)."""
        return min(-(-min(length, self.cache_len) // self.page_size), self.pages_per_slot)

    # -- transitions ---------------------------------------------------------

    def alloc(self, slot: int, n_pages: int) -> List[int]:
        """Grow ``slot``'s ownership to ``n_pages`` pages (idempotent past
        what it already holds); returns the slot's full page list in logical
        order. Raises when the pool cannot cover the growth."""
        owned = self._owned.setdefault(slot, [])
        need = n_pages - len(owned)
        if need > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: slot {slot} needs {need} more pages but only "
                f"{len(self._free)}/{self.n_pages} are free "
                f"(page_size={self.page_size}). Raise --pool-pages, shrink request "
                "budgets, or lower --max-slots."
            )
        for _ in range(max(need, 0)):
            page = self._free.pop()
            self._ref[page] = 1
            owned.append(page)
        return list(owned)

    def free_slot(self, slot: int) -> List[int]:
        """Drop ``slot``'s table (eviction/drain), decrementing each page's
        refcount; returns the pages that actually went back to the free list
        (a page still pinned by the prefix cache or another slot's table
        stays allocated)."""
        freed: List[int] = []
        for page in self._owned.pop(slot, []):
            if self._decref(page):
                freed.append(page)
        return freed

    # -- sharing (radix prefix cache) ----------------------------------------

    def _decref(self, page: int) -> bool:
        self._ref[page] -= 1
        if self._ref[page] == 0:
            del self._ref[page]
            self._free.append(page)
            return True
        return False

    def attach(self, slot: int, pages: List[int]) -> None:
        """Splice already-allocated ``pages`` into ``slot``'s table (in
        logical order, before any privately-alloc'd tail pages): the hot half
        of a prefix-cache admission. Increments each page's refcount — no
        allocation happens and the free list is untouched."""
        owned = self._owned.setdefault(slot, [])
        for page in pages:
            if page not in self._ref:
                raise RuntimeError(
                    f"attach: page {page} is not allocated — the prefix cache "
                    "handed out a stale id (evicted without decref?)"
                )
            self._ref[page] += 1
            owned.append(page)

    def incref(self, page: int) -> None:
        """Pin an allocated page with no slot table (prefix-cache insertion)."""
        if page not in self._ref:
            raise RuntimeError(f"incref: page {page} is not allocated")
        self._ref[page] += 1

    def decref(self, page: int) -> bool:
        """Drop a prefix-cache pin; True when the page went back to the free
        list (no slot table and no other pin held it)."""
        if page not in self._ref:
            raise RuntimeError(f"decref: page {page} is not allocated")
        return self._decref(page)

    def cow(self, slot: int, idx: int):
        """Copy-on-write ``slot``'s ``idx``-th table entry: swap the shared
        page for a freshly-allocated private one and return ``(old, new)``.
        The caller owns the device copy old→new before any write lands. A
        page the slot already owns exclusively is returned as-is (no copy
        needed): ``old == new``."""
        owned = self._owned.get(slot)
        if not owned or idx >= len(owned):
            raise RuntimeError(f"cow: slot {slot} has no page at index {idx}")
        old = owned[idx]
        if self._ref[old] == 1:
            return old, old
        if not self._free:
            raise RuntimeError(
                f"KV pool exhausted: slot {slot} needs a copy-on-write page "
                f"but 0/{self.n_pages} are free. Raise --pool-pages."
            )
        new = self._free.pop()
        self._ref[new] = 1
        owned[idx] = new
        self._decref(old)
        return old, new

    # -- handoff protocol ----------------------------------------------------
    #
    # A disaggregated prefill->decode handoff moves SEALED pages between two
    # pools that index two different device buffers: the sending side
    # ``donate``s (its reservation is released once the receiver has copied
    # the sealed contents out) and the receiving side ``adopt``s (fresh ids
    # in ITS buffer for the incoming pages). The page *contents* travel with
    # the handoff structure (repro.serve.engine.KVHandoff) — ids are local to
    # a pool and never cross it.

    def stage(self, n_pages: int):
        """Reserve ``n_pages`` under a fresh staging id (the in-flight half of
        a prefill→decode handoff); returns ``(sid, pages)``. The reservation
        is released by ``donate(sid)`` once the receiver has adopted the
        sealed contents — or by ``reset()``, which must not leak it."""
        sid = self._next_sid
        self._next_sid += 1
        pages = self.alloc(sid, n_pages)
        self._staged.add(sid)
        return sid, pages

    def adopt(self, slot: int, n_pages: int) -> List[int]:
        """Receiving half of a handoff: allocate ``n_pages`` fresh ids for a
        slot that owns NOTHING yet (an adopted request starts from a clean
        slot — adopting on top of live pages would orphan them)."""
        if self._owned.get(slot):
            raise RuntimeError(
                f"slot {slot} still owns {len(self._owned[slot])} page(s); adopt "
                "targets a clean slot — free_slot/donate it first"
            )
        return self.alloc(slot, n_pages)

    def donate(self, slot: int) -> List[int]:
        """Sending half of a handoff: relinquish ``slot``'s pages back to the
        free list and return their ids. The caller must have materialized (or
        issued the device copy of) the sealed page contents first — after
        donation the ids may be reissued to the next staged prefill."""
        self._staged.discard(slot)
        return self.free_slot(slot)

    def table_row(self, slot: int) -> np.ndarray:
        """The slot's full-width page-table row, scratch-padded past its
        allocation (padding entries are a safe DMA/write target, never an
        owned page)."""
        row = np.full((self.pages_per_slot,), self.scratch_page, np.int32)
        owned = self._owned.get(slot, ())
        row[: len(owned)] = owned
        return row

"""Shared latency/throughput summaries for serving runs.

The launcher (``repro.launch.serve``) and every serve perf pair
(``benchmarks.perf_hillclimb``) report the same shape of numbers — tok/s,
end-to-end latency percentiles and the queue-wait split the
:class:`repro.serve.scheduler.Completion` timestamps make visible. One
implementation keeps the definitions identical everywhere (np.percentile
with linear interpolation, queue wait = ``admitted - arrival``), so a
launcher log line and a CI artifact are directly comparable.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def percentile(xs: Sequence[float], q: float) -> float:
    """``np.percentile`` (q in [0, 100]) with an empty-safe 0.0."""
    xs = np.asarray(list(xs), np.float64)
    if xs.size == 0:
        return 0.0
    return float(np.percentile(xs, q))


def latency_summary(completions, wall_s: float) -> Dict[str, float]:
    """Percentile summary of one serving run: tok/s over ``wall_s`` plus
    p50/p95 of end-to-end latency and of its router-attributable queue-wait
    share. Keys are stable — perf artifacts and launcher logs both read
    them."""
    lats = [c.latency for c in completions]
    waits = [c.queue_wait for c in completions]
    # hand-built completions (and old artifacts) may predate first_token
    ttfts = [c.ttft for c in completions
             if getattr(c, "first_token", None) is not None]
    toks = sum(len(c.tokens) for c in completions)
    return {
        "tok_per_s": toks / max(wall_s, 1e-9),
        "tokens": float(toks),
        "p50_s": percentile(lats, 50),
        "p95_s": percentile(lats, 95),
        "queue_wait_p50_s": percentile(waits, 50),
        "queue_wait_p95_s": percentile(waits, 95),
        "ttft_p50_s": percentile(ttfts, 50),
        "ttft_p95_s": percentile(ttfts, 95),
    }

"""Radix prefix cache over the paged KV pool.

Production traffic is dominated by shared prompt prefixes (system prompts,
few-shot headers). The page pool makes vLLM-style dedup natural: this module
indexes **resident** KV pages by the token run they hold, so a hot-prefix
admission becomes a page-table splice (``KVPool.attach``) plus a short tail
prefill instead of a full prompt forward.

Granularity is one **full page**: a node caches exactly ``page_size`` tokens
worth of KV, keyed by that token chunk, and a child's meaning depends on its
whole ancestor chain — the same physical page holds *different* KV for a
different prefix, which the tree encodes for free. Partial tail pages are
never cached (their pages keep growing under decode).

Lifetime protocol (all refcounts live in :class:`repro.serve.kv_pool.KVPool`):

* ``insert`` pins each newly-indexed page (``incref``) so it survives its
  admitting slot's eviction;
* ``match`` returns the longest resident run for a prompt — the caller
  ``attach``-es those pages (incref per slot) and prefills only the tail;
* ``evict``/``make_room`` unpin LRU leaves whose page nobody else holds
  (``refcount == 1``) — a page a live slot still maps stays resident, so the
  cache can only ever return truly-orphaned pages to the free list.

Cache-only subtrees are downward-closed: a child page can only be slot-held
if its ancestors are too (matches are prefix-contiguous), so leaf-first LRU
eviction can always reach every reclaimable page.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.kv_pool import KVPool

Chunk = Tuple[int, ...]


class _Node:
    __slots__ = ("children", "page", "stamp", "parent", "chunk")

    def __init__(self, page: int, parent: Optional["_Node"], chunk: Optional[Chunk]):
        self.children: Dict[Chunk, _Node] = {}
        self.page = page  # -1 on the root sentinel
        self.stamp = 0
        self.parent = parent
        self.chunk = chunk


class PrefixCache:
    """Host-side radix index; the KV page *contents* live in the engine's
    device state and are only ever referenced by id here."""

    def __init__(self, pool: KVPool):
        self.pool = pool
        self.page_size = pool.page_size
        self._root = _Node(-1, None, None)
        self._tick = 0
        self._n_nodes = 0

    # -- bookkeeping ---------------------------------------------------------

    @property
    def cached_pages(self) -> int:
        """Pages the cache currently pins (== node count)."""
        return self._n_nodes

    def reclaimable(self) -> int:
        """Pages eviction could return to the free list right now: cached
        pages no slot table holds (refcount is exactly the cache's own pin)."""
        return sum(
            1 for node in self._iter_nodes() if self.pool.refcount(node.page) == 1
        )

    def clear(self) -> None:
        """Drop the index without touching refcounts — pair with
        ``KVPool.reset()``, which already wiped them."""
        self._root = _Node(-1, None, None)
        self._tick = 0
        self._n_nodes = 0

    def _iter_nodes(self):
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    def _chunks(self, tokens: Sequence[int]) -> List[Chunk]:
        ps = self.page_size
        n_full = len(tokens) // ps
        return [
            tuple(int(t) for t in tokens[i * ps : (i + 1) * ps])
            for i in range(n_full)
        ]

    # -- queries -------------------------------------------------------------

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest resident full-page run for ``tokens``; touches the LRU
        stamps along the path. The caller must ``attach`` the returned pages
        (or not use them) before any pool transition can evict them."""
        self._tick += 1
        node, pages = self._root, []
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            child.stamp = self._tick
            pages.append(child.page)
            node = child
        return pages

    def probe(self, tokens: Sequence[int]) -> int:
        """Resident full pages for ``tokens`` WITHOUT touching LRU state —
        used by admission capacity checks and router prefix-affinity, which
        must not age-out pages they don't end up using."""
        node, n = self._root, 0
        for chunk in self._chunks(tokens):
            node = node.children.get(chunk)
            if node is None:
                break
            n += 1
        return n

    # -- transitions ---------------------------------------------------------

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Index ``tokens``'s full pages (``pages`` is the slot's page table
        in logical order, as long as or longer than the full-page count).
        Chunks already resident are left alone — a spliced admission maps
        them to the very same page ids; fresh chunks pin their page.
        Returns the number of newly-cached pages."""
        self._tick += 1
        node, added = self._root, 0
        for i, chunk in enumerate(self._chunks(tokens)):
            child = node.children.get(chunk)
            if child is None:
                child = _Node(int(pages[i]), node, chunk)
                node.children[chunk] = child
                self.pool.incref(child.page)
                self._n_nodes += 1
                added += 1
            child.stamp = self._tick
            node = child
        return added

    def _evict(self, node: _Node) -> bool:
        """Unpin one childless node; True when its page hit the free list."""
        assert not node.children
        node.parent.children.pop(node.chunk)
        self._n_nodes -= 1
        return self.pool.decref(node.page)

    def make_room(self, n_pages: int) -> int:
        """Evict LRU reclaimable leaves until ``n_pages`` pages have returned
        to the free list (or nothing evictable remains); returns the count
        actually freed. Leaves whose page a slot still maps are skipped —
        their KV is live and eviction would free HBM out from under it."""
        freed = 0
        while freed < n_pages:
            victims = [
                node
                for node in self._iter_nodes()
                if not node.children and self.pool.refcount(node.page) == 1
            ]
            if not victims:
                break
            victim = min(victims, key=lambda node: node.stamp)
            if self._evict(victim):
                freed += 1
        return freed

from repro.fed.client import local_train, local_train_group, evaluate_cnn
from repro.fed.market import build_market, build_market_grouped, market_eval_fn

__all__ = [
    "local_train",
    "local_train_group",
    "evaluate_cnn",
    "build_market",
    "build_market_grouped",
    "market_eval_fn",
]

from repro.fed.client import local_train, evaluate_cnn
from repro.fed.market import build_market, market_eval_fn

__all__ = ["local_train", "evaluate_cnn", "build_market", "market_eval_fn"]

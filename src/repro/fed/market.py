"""Model-market simulation: partition a dataset, locally train each client,
and hand the server nothing but the pre-trained models (+ sizes).

This is the setting of the whole paper — the server-side pipeline
(:mod:`repro.core`) must work from these artifacts alone.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.train import OFLConfig, TrainConfig
from repro.core.ensemble import ensemble_logits, make_logits_all
from repro.data.partitions import partition_dataset
from repro.fed.client import evaluate_cnn, local_train
from repro.models.cnn import cnn_apply, init_cnn
from repro.utils import get_logger

log = get_logger("market")


def build_market(
    seed: int,
    x: np.ndarray,
    y: np.ndarray,
    cfg: OFLConfig,
    num_classes: int,
    archs: Optional[Sequence[str]] = None,
    local_epochs: Optional[int] = None,
) -> Tuple[List[Callable], List[Any], List[int], List[np.ndarray]]:
    """Returns (client_apply_fns, client_params, shard_sizes, shard_indices).

    ``archs``: one CNN arch id per client (heterogeneous market) or None for
    all-``cnn5``."""
    n = cfg.num_clients
    archs = list(archs) if archs else ["cnn5"] * n
    assert len(archs) == n
    parts = partition_dataset(seed, y, cfg)
    in_shape = x.shape[1:]
    tc = TrainConfig(
        optimizer="sgdm",
        learning_rate=cfg.local_lr,
        momentum=cfg.local_momentum,
        batch_size=cfg.local_batch_size,
        seed=seed,
    )
    applies, params_list, sizes = [], [], []
    epochs = cfg.local_epochs if local_epochs is None else local_epochs
    for k in range(n):
        key = jax.random.fold_in(jax.random.key(seed), k)
        p0 = init_cnn(key, archs[k], num_classes, in_shape)
        xb, yb = x[parts[k]], y[parts[k]]
        pk = local_train(partial(cnn_apply, archs[k]), p0, xb, yb, tc, epochs)
        applies.append(partial(cnn_apply, archs[k]))
        params_list.append(pk)
        sizes.append(len(parts[k]))
        acc = evaluate_cnn(applies[-1], pk, xb[: min(512, len(xb))], yb[: min(512, len(yb))])
        log.info("client %d (%s): shard=%d train-acc=%.3f", k, archs[k], len(parts[k]), acc)
    return applies, params_list, sizes, parts


def market_eval_fn(
    client_applies: List[Callable],
    client_params: List[Any],
    server_apply: Callable,
    test_x: np.ndarray,
    test_y: np.ndarray,
    batch_size: int = 512,
) -> Callable:
    """Builds eval_fn(server_params, w) -> {server_acc, ensemble_acc}.
    ``server_params=None`` skips the server forward entirely and returns only
    ``ensemble_acc`` (ensemble-only methods like FedENS have no trained
    server — evaluating a random init would be wasted work and a misleading
    number)."""
    logits_all_fn = make_logits_all(client_applies)
    client_params = tuple(client_params)

    @jax.jit
    def _ens_preds(w, xb):
        la = logits_all_fn(client_params, xb)
        return jnp.argmax(ensemble_logits(la, w), axis=-1)

    @jax.jit
    def _batch_preds(server_params, w, xb):
        srv_pred = jnp.argmax(server_apply(server_params, xb), axis=-1)
        return _ens_preds(w, xb), srv_pred

    def eval_fn(server_params, w) -> Dict[str, float]:
        ens_ok = srv_ok = 0
        for i in range(0, len(test_x), batch_size):
            xb = jnp.asarray(test_x[i : i + batch_size])
            if server_params is None:
                ep = _ens_preds(w, xb)
            else:
                ep, sp = _batch_preds(server_params, w, xb)
                srv_ok += int((np.asarray(sp) == test_y[i : i + batch_size]).sum())
            ens_ok += int((np.asarray(ep) == test_y[i : i + batch_size]).sum())
        out = {"ensemble_acc": ens_ok / len(test_x)}
        if server_params is not None:
            out["server_acc"] = srv_ok / len(test_x)
        return out

    return eval_fn

"""Model-market simulation: partition a dataset, locally train each client,
and hand the server nothing but the pre-trained models (+ sizes).

This is the setting of the whole paper — the server-side pipeline
(:mod:`repro.core`) must work from these artifacts alone.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.train import OFLConfig, TrainConfig
from repro.core.client_bank import ClientBank, make_ensemble
from repro.core.ensemble import ensemble_logits
from repro.data.partitions import partition_dataset
from repro.fed.client import evaluate_cnn, local_train, local_train_group
from repro.models.cnn import cnn_apply, init_cnn
from repro.utils import get_logger

log = get_logger("market")


def build_market(
    seed: int,
    x: np.ndarray,
    y: np.ndarray,
    cfg: OFLConfig,
    num_classes: int,
    archs: Optional[Sequence[str]] = None,
    local_epochs: Optional[int] = None,
) -> Tuple[List[Callable], List[Any], List[int], List[np.ndarray]]:
    """Returns (client_apply_fns, client_params, shard_sizes, shard_indices).

    ``archs``: one CNN arch id per client (heterogeneous market) or None for
    all-``cnn5``."""
    n = cfg.num_clients
    archs = list(archs) if archs else ["cnn5"] * n
    assert len(archs) == n
    parts = partition_dataset(seed, y, cfg)
    in_shape = x.shape[1:]
    tc = TrainConfig(
        optimizer="sgdm",
        learning_rate=cfg.local_lr,
        momentum=cfg.local_momentum,
        batch_size=cfg.local_batch_size,
        seed=seed,
    )
    applies, params_list, sizes = [], [], []
    epochs = cfg.local_epochs if local_epochs is None else local_epochs
    for k in range(n):
        key = jax.random.fold_in(jax.random.key(seed), k)
        p0 = init_cnn(key, archs[k], num_classes, in_shape)
        xb, yb = x[parts[k]], y[parts[k]]
        pk = local_train(partial(cnn_apply, archs[k]), p0, xb, yb, tc, epochs)
        applies.append(partial(cnn_apply, archs[k]))
        params_list.append(pk)
        sizes.append(len(parts[k]))
        acc = evaluate_cnn(applies[-1], pk, xb[: min(512, len(xb))], yb[: min(512, len(yb))])
        log.info("client %d (%s): shard=%d train-acc=%.3f", k, archs[k], len(parts[k]), acc)
    return applies, params_list, sizes, parts


def build_market_grouped(
    seed: int,
    x: np.ndarray,
    y: np.ndarray,
    cfg: OFLConfig,
    num_classes: int,
    archs: Optional[Sequence[str]] = None,
    local_epochs: Optional[int] = None,
) -> Tuple[ClientBank, Tuple[Any, ...], List[int], List[np.ndarray]]:
    """The grouped-bank twin of :func:`build_market`: same partition, same
    per-client inits and ``batch_iterator`` step sequences, but clients of
    the same arch train as ONE vmapped program per group
    (:func:`repro.fed.client.local_train_group`) instead of K sequential
    loops. Returns ``(bank, bank_params, shard_sizes, shard_indices)`` —
    the bank's params feed the server pipeline directly (its
    ``bank.logits_all`` is the ``logits_all_fn``), or convert back with
    ``bank.unstack_params`` for per-client APIs."""
    n = cfg.num_clients
    archs = list(archs) if archs else ["cnn5"] * n
    assert len(archs) == n
    parts = partition_dataset(seed, y, cfg)
    in_shape = x.shape[1:]
    tc = TrainConfig(
        optimizer="sgdm",
        learning_rate=cfg.local_lr,
        momentum=cfg.local_momentum,
        batch_size=cfg.local_batch_size,
        seed=seed,
    )
    epochs = cfg.local_epochs if local_epochs is None else local_epochs
    applies, inits = [], []
    for k in range(n):
        key = jax.random.fold_in(jax.random.key(seed), k)
        applies.append(partial(cnn_apply, archs[k]))
        inits.append(init_cnn(key, archs[k], num_classes, in_shape))
    bank, bank_params0 = ClientBank.build(applies, inits, scan_chunk=cfg.ensemble_scan_chunk)
    bank_params, at = [], 0
    for g, count in enumerate(bank.counts):
        members = bank.order[at : at + count]
        at += count
        shards = [(x[parts[k]], y[parts[k]]) for k in members]
        trained = local_train_group(bank.applies[g], bank_params0[g], shards, tc, epochs)
        bank_params.append(trained)
        log.info(
            "group %d (%s): %d clients, shards=%s",
            g, archs[members[0]], count, [len(s[0]) for s in shards],
        )
    sizes = [len(parts[k]) for k in range(n)]
    return bank, tuple(bank_params), sizes, parts


def market_eval_fn(
    client_applies: List[Callable],
    client_params: List[Any],
    server_apply: Callable,
    test_x: np.ndarray,
    test_y: np.ndarray,
    batch_size: int = 512,
    impl: str = "grouped",
) -> Callable:
    """Builds eval_fn(server_params, w) -> {server_acc, ensemble_acc}.
    ``server_params=None`` skips the server forward entirely and returns only
    ``ensemble_acc`` (ensemble-only methods like FedENS have no trained
    server — evaluating a random init would be wasted work and a misleading
    number). ``impl`` picks the client-forward engine (grouped ClientBank by
    default; "looped" is the unrolled parity baseline)."""
    logits_all_fn, client_params = make_ensemble(client_applies, client_params, impl=impl)

    @jax.jit
    def _ens_preds(w, xb):
        la = logits_all_fn(client_params, xb)
        return jnp.argmax(ensemble_logits(la, w), axis=-1)

    @jax.jit
    def _batch_preds(server_params, w, xb):
        srv_pred = jnp.argmax(server_apply(server_params, xb), axis=-1)
        return _ens_preds(w, xb), srv_pred

    def eval_fn(server_params, w) -> Dict[str, float]:
        ens_ok = srv_ok = 0
        for i in range(0, len(test_x), batch_size):
            xb = jnp.asarray(test_x[i : i + batch_size])
            if server_params is None:
                ep = _ens_preds(w, xb)
            else:
                ep, sp = _batch_preds(server_params, w, xb)
                srv_ok += int((np.asarray(sp) == test_y[i : i + batch_size]).sum())
            ens_ok += int((np.asarray(ep) == test_y[i : i + batch_size]).sum())
        out = {"ensemble_acc": ens_ok / len(test_x)}
        if server_params is not None:
            out["server_acc"] = srv_ok / len(test_x)
        return out

    return eval_fn

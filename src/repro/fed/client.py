"""Client-side local training (the phase that happens *before* the single
communication round — Co-Boosting never touches it, per the model-market
constraint)."""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.train import TrainConfig
from repro.core.losses import ce_loss
from repro.data.loader import batch_iterator
from repro.optim import make_optimizer
from repro.optim.optimizers import apply_updates, clip_by_global_norm


def local_train(
    apply_fn: Callable,
    params: Any,
    x: np.ndarray,
    y: np.ndarray,
    tc: TrainConfig,
    epochs: int,
) -> Any:
    """SGD-momentum local training on one client's shard (paper App. B.1:
    lr=0.01, momentum=0.9)."""
    opt = make_optimizer(tc)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, xb, yb, i):
        def loss_fn(p):
            return ce_loss(apply_fn(p, xb), yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if tc.grad_clip_norm > 0:
            grads = clip_by_global_norm(grads, tc.grad_clip_norm)
        updates, opt_state2 = opt.update(grads, opt_state, params, i)
        return apply_updates(params, updates), opt_state2, loss

    i = 0
    for xb, yb in batch_iterator(x, y, tc.batch_size, seed=tc.seed, epochs=epochs):
        params, opt_state, _ = step(params, opt_state, jnp.asarray(xb), jnp.asarray(yb), jnp.asarray(i, jnp.int32))
        i += 1
    return params


def evaluate_cnn(
    apply_fn: Callable, params: Any, x: np.ndarray, y: np.ndarray, batch_size: int = 512
) -> float:
    """Top-1 accuracy."""

    @jax.jit
    def pred(params, xb):
        return jnp.argmax(apply_fn(params, xb), axis=-1)

    correct = 0
    for i in range(0, len(x), batch_size):
        xb = jnp.asarray(x[i : i + batch_size])
        p = np.asarray(pred(params, xb))
        correct += int((p == y[i : i + batch_size]).sum())
    return correct / len(x)

"""Client-side local training (the phase that happens *before* the single
communication round — Co-Boosting never touches it, per the model-market
constraint)."""
from __future__ import annotations

from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.train import TrainConfig
from repro.core.losses import ce_loss, ce_per_sample
from repro.data.loader import batch_iterator
from repro.optim import make_optimizer
from repro.optim.optimizers import apply_updates, clip_by_global_norm


def local_train(
    apply_fn: Callable,
    params: Any,
    x: np.ndarray,
    y: np.ndarray,
    tc: TrainConfig,
    epochs: int,
) -> Any:
    """SGD-momentum local training on one client's shard (paper App. B.1:
    lr=0.01, momentum=0.9)."""
    opt = make_optimizer(tc)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, xb, yb, i):
        def loss_fn(p):
            return ce_loss(apply_fn(p, xb), yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if tc.grad_clip_norm > 0:
            grads = clip_by_global_norm(grads, tc.grad_clip_norm)
        updates, opt_state2 = opt.update(grads, opt_state, params, i)
        return apply_updates(params, updates), opt_state2, loss

    i = 0
    for xb, yb in batch_iterator(x, y, tc.batch_size, seed=tc.seed, epochs=epochs):
        params, opt_state, _ = step(params, opt_state, jnp.asarray(xb), jnp.asarray(yb), jnp.asarray(i, jnp.int32))
        i += 1
    return params


def _group_schedule(
    shard_sizes: Sequence[int], batch_size: int, seed: int, epochs: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side replica of every group member's ``batch_iterator`` walk.

    For each client: per-epoch ``RandomState(seed+e)`` shuffle, contiguous
    batches, partial last batch kept (padded up to ``batch_size`` and
    masked). Clients with fewer steps than the group max get invalid
    (masked-out) trailing steps. Returns ``(idx, m, valid)`` with shapes
    ``(S, G, B)``, ``(S, G, B)``, ``(S, G)`` — step-major so the device scan
    slices one step for the whole group at a time.
    """
    G, B = len(shard_sizes), batch_size
    steps = [epochs * -(-n // B) for n in shard_sizes]
    S = max(steps)
    idx = np.zeros((G, S, B), np.int32)
    m = np.zeros((G, S, B), np.float32)
    valid = np.zeros((G, S), bool)
    for k, n in enumerate(shard_sizes):
        t = 0
        for e in range(epochs):
            order = np.random.RandomState(seed + e).permutation(n)
            for i in range(0, n, B):
                b = order[i : i + B]
                idx[k, t, : len(b)] = b
                m[k, t, : len(b)] = 1.0
                valid[k, t] = True
                t += 1
    return idx.swapaxes(0, 1), m.swapaxes(0, 1), valid.swapaxes(0, 1)


def local_train_group(
    apply_fn: Callable,
    stacked_params: Any,
    shards: Sequence[Tuple[np.ndarray, np.ndarray]],
    tc: TrainConfig,
    epochs: int,
) -> Any:
    """Local training for one homogeneous client group as a single jitted
    program: ``lax.scan`` over steps, ``vmap`` over the group's client axis.

    Matches per-client :func:`local_train` semantics exactly — same
    ``batch_iterator`` batch composition per client (replicated host-side by
    :func:`_group_schedule`), same masked-mean CE on partial batches
    (``sum(ce·mask)/count`` == the legacy per-batch mean), and clients whose
    shard yields fewer steps than the group max simply stop updating
    (masked param/optimizer carry-through), so unbalanced shards never see
    extra steps.

    ``stacked_params``: the group's init params with clients on the leading
    axis; ``shards``: one ``(x_k, y_k)`` pair per client, any sizes.
    """
    opt = make_optimizer(tc)
    G = len(shards)
    sizes = [len(x) for x, _ in shards]
    max_n = max(sizes)
    x0 = np.asarray(shards[0][0])
    X = np.zeros((G, max_n, *x0.shape[1:]), x0.dtype)
    Y = np.zeros((G, max_n), np.asarray(shards[0][1]).dtype)
    for k, (xk, yk) in enumerate(shards):
        X[k, : sizes[k]] = xk
        Y[k, : sizes[k]] = yk
    idx, m, valid = _group_schedule(sizes, tc.batch_size, tc.seed, epochs)

    def one_client(params, opt_state, xk, yk, idx_t, m_t, valid_t, i):
        xb, yb = xk[idx_t], yk[idx_t]

        def loss_fn(p):
            ce = ce_per_sample(apply_fn(p, xb), yb)
            return jnp.sum(ce * m_t) / jnp.maximum(jnp.sum(m_t), 1.0)

        _, grads = jax.value_and_grad(loss_fn)(params)
        if tc.grad_clip_norm > 0:
            grads = clip_by_global_norm(grads, tc.grad_clip_norm)
        updates, opt_state2 = opt.update(grads, opt_state, params, i)
        params2 = apply_updates(params, updates)
        keep = lambda old, new: jax.tree_util.tree_map(
            lambda a, b: jnp.where(valid_t, b, a), old, new
        )
        return keep(params, params2), keep(opt_state, opt_state2)

    @jax.jit
    def run(stacked_params, X, Y, idx, m, valid):
        opt_state = jax.vmap(opt.init)(stacked_params)

        def body(carry, sched):
            params, st = carry
            idx_t, m_t, valid_t, i = sched
            params, st = jax.vmap(one_client, in_axes=(0, 0, 0, 0, 0, 0, 0, None))(
                params, st, X, Y, idx_t, m_t, valid_t, i
            )
            return (params, st), None

        S = idx.shape[0]
        (params, _), _ = jax.lax.scan(
            body, (stacked_params, opt_state),
            (idx, m, valid, jnp.arange(S, dtype=jnp.int32)),
        )
        return params

    return run(stacked_params, jnp.asarray(X), jnp.asarray(Y), jnp.asarray(idx), jnp.asarray(m), jnp.asarray(valid))


def evaluate_cnn(
    apply_fn: Callable, params: Any, x: np.ndarray, y: np.ndarray, batch_size: int = 512
) -> float:
    """Top-1 accuracy."""

    @jax.jit
    def pred(params, xb):
        return jnp.argmax(apply_fn(params, xb), axis=-1)

    correct = 0
    for i in range(0, len(x), batch_size):
        xb = jnp.asarray(x[i : i + batch_size])
        p = np.asarray(pred(params, xb))
        correct += int((p == y[i : i + batch_size]).sum())
    return correct / len(x)

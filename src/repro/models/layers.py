"""Shared neural-net layers (pure-JAX functional; params are nested dicts)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def dense_init(key, in_dim: int, out_shape: Tuple[int, ...], dtype=jnp.float32, scale: float = 1.0):
    """Fan-in scaled normal initializer; ``out_shape`` may be multi-dim
    (e.g. ``(H, hd)`` for per-head projections)."""
    stddev = scale / jnp.sqrt(jnp.asarray(in_dim, jnp.float32))
    return (jax.random.normal(key, (in_dim, *out_shape)) * stddev).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, wi.astype(x.dtype))
    g = jnp.einsum("...d,df->...f", x, wg.astype(x.dtype))
    h = h * jax.nn.silu(g)
    h = constrain(h, "batch", None, "tp")
    return jnp.einsum("...f,fd->...d", h, wo.astype(x.dtype))


def gelu_mlp(x: jax.Array, wi: jax.Array, wo: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, wi.astype(x.dtype)))
    h = constrain(h, "batch", None, "tp")
    return jnp.einsum("...f,fd->...d", h, wo.astype(x.dtype))


def init_mlp(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "silu":
        return {
            "wi": dense_init(k1, d, (f,), dtype),
            "wg": dense_init(k2, d, (f,), dtype),
            "wo": dense_init(k3, f, (d,), dtype),
        }
    return {
        "wi": dense_init(k1, d, (f,), dtype),
        "wo": dense_init(k3, f, (d,), dtype),
    }


def apply_mlp(params, x, cfg):
    if "wg" in params:
        return swiglu(x, params["wi"], params["wg"], params["wo"])
    return gelu_mlp(x, params["wi"], params["wo"])

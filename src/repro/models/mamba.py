"""Mamba (S6) selective-state-space mixer.

TPU adaptation: the reference CUDA implementation fuses the selective scan
into a single kernel with warp-level parallel prefix sums. On TPU we express
the same recurrence ``h_t = a_t * h_{t-1} + b_t`` as a *chunked* scan — a
``lax.scan`` over chunks of ``cfg.ssm_chunk`` steps carrying the (B, inner,
N) state, with a ``jax.lax.associative_scan`` (log-depth, VPU-friendly)
inside each chunk. This bounds the materialized (chunk, inner, N) tensor to
a VMEM-sized working set while keeping O(log chunk) sequential depth, which
is the TPU-native analogue of the CUDA kernel's shared-memory scan.

Decode is the plain O(1) recurrent step on state {conv tail, h}.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding import constrain


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    inner = cfg.ssm_inner
    n = cfg.ssm_state_dim
    r = cfg.dt_rank_
    keys = jax.random.split(key, 6)
    # A initialized to -[1..N] per channel (S4D-real), stored as log.
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (inner, 1))
    return {
        "in_proj": dense_init(keys[0], d, (2 * inner,), dtype),
        "conv": dense_init(keys[1], cfg.ssm_conv_dim, (inner,), dtype, scale=1.0),
        "x_proj": dense_init(keys[2], inner, (r + 2 * n,), dtype),
        "dt_proj": dense_init(keys[3], r, (inner,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((inner,), 1e-2))).astype(jnp.float32),
        "A_log": jnp.log(a_init),
        "D": jnp.ones((inner,), jnp.float32),
        "out_proj": dense_init(keys[4], inner, (d,), dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B, S, inner); w: (K, inner).
    ``state``: (B, K-1, inner) tail of the previous segment (decode/prefill
    carry) or None for zero history. Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    return y, xp[:, -(k - 1) :] if k > 1 else jnp.zeros_like(state)


def _ssm_params(params, xc, cfg):
    """xc: (B, S, inner) post-conv activations -> (dt, B_ssm, C_ssm, A)."""
    n, r = cfg.ssm_state_dim, cfg.dt_rank_
    dbc = jnp.einsum("bsi,ip->bsp", xc, params["x_proj"].astype(xc.dtype))
    dt, b_ssm, c_ssm = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jnp.einsum("bsr,ri->bsi", dt, params["dt_proj"].astype(xc.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])  # (inner, N)
    return dt, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32), a


def _chunk_scan(dt, b_ssm, c_ssm, a, xc, h0):
    """Selective scan over one chunk (parallel within the chunk).

    dt: (B,Q,inner) f32;  b_ssm,c_ssm: (B,Q,N);  a: (inner,N);
    xc: (B,Q,inner);  h0: (B,inner,N).  Returns (y (B,Q,inner) f32, hQ)."""
    da = jnp.exp(dt[..., None] * a[None, None])  # (B,Q,inner,N) decay
    db = dt[..., None] * b_ssm[:, :, None, :] * xc.astype(jnp.float32)[..., None]
    # fold carry into the first step: h_1 = da_1 h0 + db_1
    db = db.at[:, 0].add(da[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (da, db), axis=1)
    y = jnp.einsum("bqin,bqn->bqi", h, c_ssm)
    return y, h[:, -1]


def mamba_forward(params, x, cfg, state: Dict = None, return_state: bool = False):
    """x: (B, S, d). Returns y (B, S, d) [, new_state]."""
    b, s, d = x.shape
    xz = jnp.einsum("bsd,di->bsi", x, params["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, "batch", None, "tp")
    conv_state = None if state is None else state["conv"]
    xc, conv_tail = _causal_conv(xin, params["conv"].astype(x.dtype), conv_state)
    xc = jax.nn.silu(xc)
    dt, b_ssm, c_ssm, a = _ssm_params(params, xc, cfg)

    q = min(cfg.ssm_chunk, s)
    pad = (-s) % q
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ssm = jnp.pad(b_ssm, ((0, 0), (0, pad), (0, 0)))
        c_ssm = jnp.pad(c_ssm, ((0, 0), (0, pad), (0, 0)))
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    nq = (s + pad) // q
    inner, n = a.shape
    h0 = jnp.zeros((b, inner, n), jnp.float32) if state is None else state["h"]

    def step(h, blk):
        dtq, bq, cq, xq = blk
        y, hq = _chunk_scan(dtq, bq, cq, a, xq, h)
        return hq, y

    # checkpoint the chunk body: autodiff would otherwise save the (B, Q,
    # inner, N) decay/input tensors of EVERY chunk as scan residuals; with
    # the checkpoint only chunk-boundary states are kept and the backward
    # recomputes one chunk at a time.
    step = jax.checkpoint(step, prevent_cse=False)
    reshape = lambda t: t.reshape(b, nq, q, *t.shape[2:]).swapaxes(0, 1)
    hF, ys = jax.lax.scan(step, h0, (reshape(dt), reshape(b_ssm), reshape(c_ssm), reshape(xc)))
    y = ys.swapaxes(0, 1).reshape(b, nq * q, inner)[:, :s]
    y = y + xc[:, :s].astype(jnp.float32) * params["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"].astype(x.dtype))
    out = constrain(out, "batch", None, None)
    if return_state:
        return out, {"conv": conv_tail, "h": hF}
    return out


def init_mamba_state(cfg, batch: int, dtype) -> Dict:
    inner, n = cfg.ssm_inner, cfg.ssm_state_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, inner), dtype),
        "h": jnp.zeros((batch, inner, n), jnp.float32),
    }


def mamba_decode(params, x, cfg, state):
    """One-token step. x: (B, 1, d)."""
    out, new_state = mamba_forward(params, x, cfg, state=state, return_state=True)
    return out, new_state

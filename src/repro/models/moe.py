"""Mixture-of-Experts FFN with top-k routing and two dispatch strategies.

``moe_impl = "einsum"`` — the GShard/Mesh-TF capacity dispatch: tokens are
grouped, a (group, token, expert, capacity) one-hot routes them through two
large dispatch/combine einsums. This is the *baseline*: it compiles and
shards cleanly under pjit (the expert dim carries the ``experts`` logical
axis → ``model`` mesh axis, so XLA inserts the all-to-all-shaped
collectives), but the dispatch einsums burn real MXU FLOPs proportional to
``tokens × E × C × d_model`` — quadratic in group size. The roofline's
"useful-FLOPs ratio" metric exposes exactly this waste.

``moe_impl = "scatter"`` — the optimized path (§Perf hillclimb): the same
capacity buffer is filled with a scatter-add and read back with a gather, so
the only matmul FLOPs are the expert FFNs themselves (``capacity_factor``×
the useful compute). TPU adaptation note: on GPU this niche is filled by
MegaBlocks' block-sparse kernels; on TPU, scatter/gather lower to efficient
dynamic-update-slice sequences and the expert matmuls stay MXU-aligned, so
no custom kernel is needed — the win is structural (removing the dispatch
einsum), not micro-architectural.

Both paths drop tokens that overflow an expert's capacity (``gates`` zeroed),
identically, so they are numerically equivalent and are property-tested
against the dense oracle :func:`moe_ref` (no drops when capacity is ample).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding import constrain


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    keys = jax.random.split(key, 4)
    params = {
        "router": dense_init(keys[0], d, (e,), jnp.float32),
        "wi": dense_init(keys[1], d, (e, f), dtype).transpose(1, 0, 2),  # (E, d, f)
        "wg": dense_init(keys[2], d, (e, f), dtype).transpose(1, 0, 2),
        "wo": dense_init(keys[3], f, (e, d), dtype).transpose(1, 0, 2),  # (E, f, d)
    }
    return params


def _router(params, x, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Return (gates, expert_idx, aux_loss). x: (..., d)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    e = cfg.num_experts
    me = jnp.mean(probs.reshape(-1, e), axis=0)  # mean router prob per expert
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx.reshape(-1, cfg.experts_per_token), e), axis=1), axis=0
    )  # fraction of tokens dispatched per expert
    aux = e * jnp.sum(me * fe)
    return gates, idx, aux


def _capacity(cfg, tokens_per_group: int) -> int:
    c = tokens_per_group * cfg.experts_per_token / cfg.num_experts
    c = int(-(-c * cfg.moe_capacity_factor // 1))  # ceil
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8 (lane-friendly)


def _positions_in_expert(idx, cfg):
    """idx: (G, S, k) expert assignment. Returns (G, S, k) int position of each
    token-slot within its expert's capacity buffer (tokens first, then k)."""
    g, s, k = idx.shape
    e = cfg.num_experts
    oh = jax.nn.one_hot(idx, e, dtype=jnp.int32).reshape(g, s * k, e)
    before = jnp.cumsum(oh, axis=1) - oh  # slots assigned to the expert earlier
    pos = jnp.sum(before * oh, axis=-1).reshape(g, s, k)
    return pos


def _group(x, cfg, seq_len):
    """(B, S, d) -> (G, Sg, d)."""
    b, s, d = x.shape
    sg = cfg.moe_group_size or seq_len
    sg = min(sg, b * s)
    g = (b * s) // sg
    return x.reshape(g, sg, d), (b, s)


def moe_apply_einsum(params, x, cfg):
    """GShard-style capacity dispatch via one-hot einsums. x: (B, S, d)."""
    xg, (b, s) = _group(x, cfg, x.shape[1])
    g, sg, d = xg.shape
    gates, idx, aux = _router(params, xg, cfg)  # (G,Sg,k)
    cap = _capacity(cfg, sg)
    pos = _positions_in_expert(idx, cfg)
    keep = (pos < cap).astype(xg.dtype)
    gates = gates.astype(xg.dtype) * keep
    e_oh = jax.nn.one_hot(idx, cfg.num_experts, dtype=xg.dtype)  # (G,Sg,k,E)
    c_oh = jax.nn.one_hot(pos, cap, dtype=xg.dtype) * keep[..., None]  # (G,Sg,k,C)
    dispatch = jnp.einsum("gske,gskc->gsec", e_oh, c_oh)
    combine = jnp.einsum("gsk,gske,gskc->gsec", gates, e_oh, c_oh)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    xe = constrain(xe, None, "experts", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"].astype(xg.dtype))
    hg = jnp.einsum("gecd,edf->gecf", xe, params["wg"].astype(xg.dtype))
    h = h * jax.nn.silu(hg)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(xg.dtype))
    ye = constrain(ye, None, "experts", None, None)
    y = jnp.einsum("gecd,gsec->gsd", ye, combine)
    return y.reshape(b, s, d), aux


def moe_apply_scatter(params, x, cfg):
    """Scatter/gather capacity dispatch — same routing and drop semantics as
    :func:`moe_apply_einsum`, but the capacity buffer is filled with a
    scatter-add and read back with a gather, so the only matmul FLOPs are the
    expert FFNs. x: (B, S, d)."""
    xg, (b, s) = _group(x, cfg, x.shape[1])
    g, sg, d = xg.shape
    k = cfg.experts_per_token
    gates, idx, aux = _router(params, xg, cfg)
    cap = _capacity(cfg, sg)
    pos = _positions_in_expert(idx, cfg)
    keep = pos < cap
    slot = jnp.where(keep, idx * cap + pos, cfg.num_experts * cap)  # OOB => dropped
    slot = slot.reshape(g, sg * k)
    xk = jnp.broadcast_to(xg[:, :, None, :], (g, sg, k, d)).reshape(g, sg * k, d)
    buf = jnp.zeros((g, cfg.num_experts * cap, d), xg.dtype)
    gi = jnp.arange(g)[:, None]
    buf = buf.at[gi, slot].add(xk, mode="drop")
    xe = buf.reshape(g, cfg.num_experts, cap, d)
    xe = constrain(xe, None, "experts", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"].astype(xg.dtype))
    hg = jnp.einsum("gecd,edf->gecf", xe, params["wg"].astype(xg.dtype))
    h = h * jax.nn.silu(hg)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(xg.dtype))
    ye = constrain(ye, None, "experts", None, None)
    yk = ye.reshape(g, cfg.num_experts * cap, d)[gi, slot]  # gather (OOB => fill)
    yk = jnp.where(keep.reshape(g, sg * k, 1), yk, 0.0)
    y = jnp.sum(
        yk.reshape(g, sg, k, d) * gates.astype(xg.dtype)[..., None], axis=2
    )
    return y.reshape(b, s, d), aux


def moe_apply(params, x, cfg):
    if cfg.moe_impl == "scatter":
        return moe_apply_scatter(params, x, cfg)
    return moe_apply_einsum(params, x, cfg)


def moe_ref(params, x, cfg):
    """Dense oracle: every token through every expert, combined by top-k
    gates. O(E) overcompute — tests only."""
    gates, idx, aux = _router(params, x, cfg)
    h = jnp.einsum("bsd,edf->besf", x, params["wi"].astype(x.dtype))
    hg = jnp.einsum("bsd,edf->besf", x, params["wg"].astype(x.dtype))
    h = h * jax.nn.silu(hg)
    ye = jnp.einsum("besf,efd->besd", h, params["wo"].astype(x.dtype))  # (B,E,S,d)
    comb = jnp.sum(
        jax.nn.one_hot(idx, cfg.num_experts, dtype=x.dtype) * gates.astype(x.dtype)[..., None],
        axis=2,
    )  # (B,S,E)
    y = jnp.einsum("besd,bse->bsd", ye, comb)
    return y, aux

"""Pure-JAX functional model zoo.

LM families (dense/moe/ssm/hybrid/audio/vlm) live in
:mod:`repro.models.transformer`; the paper's own CNN client zoo in
:mod:`repro.models.cnn`; data-free generators in
:mod:`repro.models.generator`.
"""
from repro.models.transformer import (
    init_lm,
    lm_forward,
    lm_loss,
    lm_logits,
    lm_prefill,
    lm_decode,
    lm_extend,
    init_lm_state,
    layer_kinds,
    group_period,
    group_pattern,
    num_groups,
    cross_entropy,
)
from repro.models.cnn import CNN_ARCHS, init_cnn, cnn_apply, make_cnn
from repro.models.generator import (
    init_image_generator,
    image_generator,
    init_embedding_generator,
    embedding_generator,
)

__all__ = [
    "init_lm",
    "lm_forward",
    "lm_loss",
    "lm_logits",
    "lm_prefill",
    "lm_decode",
    "lm_extend",
    "init_lm_state",
    "layer_kinds",
    "group_period",
    "group_pattern",
    "num_groups",
    "cross_entropy",
    "CNN_ARCHS",
    "init_cnn",
    "cnn_apply",
    "make_cnn",
    "init_image_generator",
    "image_generator",
    "init_embedding_generator",
    "embedding_generator",
]

"""Grouped-query attention with RoPE, optional qk-norm, sliding window, and a
ring-buffer KV cache for decode.

Training/prefill attention is *blocked* (flash-style online softmax over KV
chunks inside a scan over Q chunks) so a 32k-token prefill never materializes
an (S, S) score matrix — memory is O(S · block). The Pallas kernel in
:mod:`repro.kernels.flash_attention` is the TPU-tiled version of the same
algorithm; this module is its lowering-friendly pure-JAX twin.

Entry points:
  * :func:`attn_train`   — full-sequence causal (or bidirectional) attention;
  * :func:`attn_prefill` — like train but also returns the filled KV cache;
  * :func:`attn_decode`  — one-token step against an existing cache;
  * :func:`attn_extend`  — an S-token run against an existing PAGED cache at
    per-row start positions (spliced-tail prefill / speculative verify).

The cache for sliding-window layers is a ring buffer of ``window`` slots so a
500k-token context costs O(window) memory for SWA archs.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve
from repro.kernels.flash_attention.kernel import (
    flash_attention_bwd_pallas,
    flash_attention_pallas,
)
from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.sharding import constrain

NEG_INF = -1e30
DEFAULT_Q_BLOCK = 512
DEFAULT_KV_BLOCK = 1024


def init_attention(key, cfg, dtype):
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    keys = jax.random.split(key, 4)
    params = {
        "wq": dense_init(keys[0], d, (h, hd), dtype),
        "wk": dense_init(keys[1], d, (k, hd), dtype),
        "wv": dense_init(keys[2], d, (k, hd), dtype),
        "wo": dense_init(keys[3], h * hd, (d,), dtype).reshape(h, hd, d),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.zeros((hd,), dtype)
        params["k_norm"] = jnp.zeros((hd,), dtype)
    return params


def _project_qkv(params, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)
    return q, k, v


def _block_bias(q_pos, k_pos, causal: bool, window: int) -> jax.Array:
    """(cq, ck) additive bias for one (q-block, kv-block) pair."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def _pad_blocks(q, k, v, q_block, kv_block):
    """Blocked layout with K/V broadcast to the FULL head count.

    GQA archs whose (kv_heads, q_per_kv) split cannot shard the model axis
    (granite: 8×4 over 16 devices) would replicate every score tile if the
    blocked tensors carried separate (kh, g) dims — measured 27+ GB/device
    temps. Broadcasting K/V to h = kh·g heads keeps ONE head dim that
    shards cleanly whenever h divides the axis; the broadcast itself is
    tiny (K/V are the small operands) and dk/dv are reduced back over g at
    the end."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)  # h ordered (kh major, g minor)
        v = jnp.repeat(v, g, axis=2)
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    pq = (-sq) % q_block
    pk = (-sk) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // q_block, k.shape[1] // kv_block
    qb = q.reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4)  # (nq,B,H,cq,hd)
    kb = k.reshape(b, nk, kv_block, h, hd).transpose(1, 0, 3, 2, 4)  # (nk,B,H,ck,hd)
    vb = v.reshape(b, nk, kv_block, h, hd).transpose(1, 0, 3, 2, 4)
    return qb, kb, vb, (b, sq, sk, h, kh, g, hd, nq, nk, q_block, kv_block)


def _scores(qblk, kblk, qi, ki, dims, causal, window, softcap, q_offset, scale):
    """One (q-block, kv-block) score tile with masking. Returns (s, dact)
    where dact is the softcap chain factor (1 where no softcap)."""
    b, sq, sk, h, kh, g, hd, nq, nk, q_block, kv_block = dims
    s = jnp.einsum("bhqd,bhcd->bhqc", qblk, kblk).astype(jnp.float32) * scale
    if softcap > 0:
        t = jnp.tanh(s / softcap)
        dact = 1.0 - t * t
        s = t * softcap
    else:
        dact = jnp.ones_like(s)
    q_pos = q_offset + qi * q_block + jnp.arange(q_block, dtype=jnp.int32)
    k_pos = ki * kv_block + jnp.arange(kv_block, dtype=jnp.int32)
    bias = _block_bias(q_pos, k_pos, causal, window)
    bias = jnp.where((k_pos < sk)[None, :], bias, NEG_INF)
    return s + bias[None, None], dact


def _constrain_blocked(x, total_heads: int):
    """Shard a (n_blocks, B, H, ...) blocked tensor over the model axis:
    prefer the head dim (dim 2) when it divides; otherwise fall back to the
    vmapped BLOCK dim (smollm's 9 heads would otherwise replicate the whole
    sequence on all model-axis devices — measured 13–16× attention
    overcompute). A lax.scan over blocks is inherently sequential and
    cannot split this way, which is why blocks are vmapped."""
    from repro.sharding import constrain as _c
    from repro.sharding.partition import _mesh_axes

    axes = _mesh_axes()
    model = axes.get("model", 1)
    if model > 1 and total_heads % model == 0:
        return _c(x, None, "batch", "heads", *([None] * (x.ndim - 3)))
    return _c(x, "seq", "batch", *([None] * (x.ndim - 2)))


def _flash_fwd_impl(q, k, v, causal, window, softcap, q_block, kv_block, q_offset):
    qb, kb, vb, dims = _pad_blocks(q, k, v, q_block, kv_block)
    b, sq, sk, h, kh, g, hd, nq, nk, q_block, kv_block = dims
    scale = 1.0 / float(hd) ** 0.5
    qb = _constrain_blocked(qb, h)
    kb = _constrain_blocked(kb, h)
    vb = _constrain_blocked(vb, h)

    def q_row(qi, qblk):
        def kv_step(carry, ki_and_blocks):
            m, l, acc = carry
            ki, kblk, vblk = ki_and_blocks
            s, _ = _scores(qblk, kblk, qi, ki, dims, causal, window, softcap, q_offset, scale)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqc,bhcd->bhqd", p.astype(vblk.dtype), vblk)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        a0 = jnp.zeros((b, h, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk, dtype=jnp.int32), kb, vb)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 1e30)
        return out.astype(q.dtype), lse

    outs, lses = jax.vmap(q_row)(jnp.arange(nq, dtype=jnp.int32), qb)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_block, h, hd)
    return out[:, :sq], lses  # lses: (nq, B, H, cq)


def _flash_bwd_impl(res, dout, causal, window, softcap, q_block, kv_block, q_offset):
    """Flash-attention backward: recompute scores block-by-block — O(block)
    live memory instead of O(S²) saved probabilities. Two vmapped passes
    (dq over q-blocks; dk/dv over kv-blocks), standard for flash VJPs."""
    q, k, v, out, lses = res
    qb, kb, vb, dims = _pad_blocks(q, k, v, q_block, kv_block)
    b, sq, sk, h, kh, g, hd, nq, nk, q_block, kv_block = dims
    scale = 1.0 / float(hd) ** 0.5
    pq = nq * q_block - sq
    if pq:
        dout = jnp.pad(dout, ((0, 0), (0, pq), (0, 0), (0, 0)))
        out = jnp.pad(out, ((0, 0), (0, pq), (0, 0), (0, 0)))
    dob = dout.reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4)
    ob = out.reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4)
    dsum = jnp.sum(dob.astype(jnp.float32) * ob.astype(jnp.float32), axis=-1)  # (nq,B,H,cq)
    qb = _constrain_blocked(qb, h)
    kb = _constrain_blocked(kb, h)
    vb = _constrain_blocked(vb, h)
    dob = _constrain_blocked(dob, h)

    qis = jnp.arange(nq, dtype=jnp.int32)
    kis = jnp.arange(nk, dtype=jnp.int32)

    def _block_grads(qi, ki, qblk, kblk, vblk, doutb, lseb, db):
        """Recomputed (p, ds) for one (q-block, kv-block) tile."""
        s, dact = _scores(qblk, kblk, qi, ki, dims, causal, window, softcap, q_offset, scale)
        p = jnp.exp(s - lseb[..., None])  # (B,H,cq,ck)
        doutf = doutb.astype(jnp.float32)
        dp = jnp.einsum("bhqd,bhcd->bhqc", doutf, vblk.astype(jnp.float32))
        ds = p * (dp - db[..., None]) * dact
        return p, ds, doutf

    # pass 1 — dq: vmap over q blocks (shardable), scan over kv blocks.
    def dq_row(qi, qblk, doutb, lseb, db):
        def kv_step(dq, kv_in):
            ki, kblk, vblk = kv_in
            _, ds, _ = _block_grads(qi, ki, qblk, kblk, vblk, doutb, lseb, db)
            return dq + scale * jnp.einsum("bhqc,bhcd->bhqd", ds, kblk.astype(jnp.float32)), None

        dq0 = jnp.zeros((b, h, q_block, hd), jnp.float32)
        dq, _ = jax.lax.scan(kv_step, dq0, (kis, kb, vb))
        return dq

    dq = jax.vmap(dq_row)(qis, qb, dob, lses, dsum)

    # pass 2 — dk, dv: vmap over kv blocks (shardable), scan over q blocks.
    def dkv_col(ki, kblk, vblk):
        def q_step(carry, q_in):
            dk_b, dv_b = carry
            qi, qblk, doutb, lseb, db = q_in
            p, ds, doutf = _block_grads(qi, ki, qblk, kblk, vblk, doutb, lseb, db)
            dv_b = dv_b + jnp.einsum("bhqc,bhqd->bhcd", p, doutf)
            dk_b = dk_b + scale * jnp.einsum("bhqc,bhqd->bhcd", ds, qblk.astype(jnp.float32))
            return (dk_b, dv_b), None

        zeros = jnp.zeros((b, h, kv_block, hd), jnp.float32)
        (dk_b, dv_b), _ = jax.lax.scan(q_step, (zeros, zeros), (qis, qb, dob, lses, dsum))
        return dk_b, dv_b

    dkb, dvb = jax.vmap(dkv_col)(kis, kb, vb)

    dq = dq.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_block, h, hd)[:, :sq]
    dk_h = dkb.transpose(1, 0, 3, 2, 4).reshape(b, nk * kv_block, h, hd)[:, : k.shape[1]]
    dv_h = dvb.transpose(1, 0, 3, 2, 4).reshape(b, nk * kv_block, h, hd)[:, : v.shape[1]]
    # reduce the g broadcast copies back onto the kv heads
    dk = dk_h.reshape(*dk_h.shape[:2], kh, g, hd).sum(3)
    dv = dv_h.reshape(*dv_h.shape[:2], kh, g, hd).sum(3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, softcap, q_block, kv_block, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, softcap, q_block, kv_block, q_offset)
    return out


def _flash_fwd_rule(q, k, v, causal, window, softcap, q_block, kv_block, q_offset):
    out, lses = _flash_fwd_impl(q, k, v, causal, window, softcap, q_block, kv_block, q_offset)
    return out, (q, k, v, out, lses)


def _flash_bwd_rule(causal, window, softcap, q_block, kv_block, q_offset, res, dout):
    return _flash_bwd_impl(res, dout, causal, window, softcap, q_block, kv_block, q_offset)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attn_jax(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    softcap: float = 0.0,
    q_block: int = 0,
    kv_block: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Blocked attention with online softmax and a flash-style custom VJP.

    q: (B, Sq, H, hd); k, v: (B, Sk, K, hd) with H % K == 0.
    Returns (B, Sq, H, hd). Never materializes (Sq, Sk) — in either pass:
    the custom backward recomputes score blocks instead of letting autodiff
    save every block's probabilities as scan residuals (which would be
    O(S²) and was measured at ~30 GB/device for a 4k-token train step).

    Default block sizes adapt so the number of q/kv blocks is a multiple of
    16 where possible — the blocks are vmapped and sharded over the model
    axis (see _constrain_blocked), so block count must divide the axis."""
    if q_block <= 0:
        q_block = min(DEFAULT_Q_BLOCK, max(128, q.shape[1] // 16))
    if kv_block <= 0:
        kv_block = min(DEFAULT_KV_BLOCK, max(128, k.shape[1] // 16))
    return _flash(q, k, v, causal, window, softcap, q_block, kv_block, q_offset)


# ---------------------------------------------------------------------------
# Pallas-kernel-backed attention (dispatch backends "pallas"/"pallas-interpret")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_pallas(q, k, v, causal, window, softcap, interpret):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap, interpret=interpret
    )


def _flash_pallas_fwd(q, k, v, causal, window, softcap, interpret):
    out, lse = flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=interpret, return_lse=True,
    )
    return out, (q, k, v, out, lse)


def _flash_pallas_bwd(causal, window, softcap, interpret, res, dout):
    """Backward for the Pallas forward: the fused Pallas backward kernels
    (dq with kv minor, dk/dv with q minor), fed the forward kernel's
    online-softmax lse as the residual — no score block is ever
    re-materialized, in the same backend (compiled or interpret) as the
    forward."""
    q, k, v, out, lse = res
    return flash_attention_bwd_pallas(
        q, k, v, out, lse, dout,
        causal=causal, window=window, softcap=softcap, interpret=interpret,
    )


_flash_pallas.defvjp(_flash_pallas_fwd, _flash_pallas_bwd)


def _attn_mix(q, k, v, cfg):
    """Full-sequence (train/prefill) attention core, routed through the
    kernel dispatch layer: ``cfg.backend_for("attn")`` (the BackendPolicy,
    or the deprecated ``attn_backend`` alias) — "auto" runs the compiled
    Pallas flash kernel on TPU and the blocked-jnp twin elsewhere (auto
    never interprets off-TPU); "ref" is the jnp twin explicitly — the parity
    oracle for the kernel path."""
    backend = resolve("attn", cfg.backend_for("attn"))
    if backend == "ref":
        return flash_attn_jax(
            q, k, v, causal=cfg.causal, window=cfg.sliding_window,
            softcap=cfg.attn_logit_softcap,
        )
    return _flash_pallas(
        q, k, v, cfg.causal, cfg.sliding_window, cfg.attn_logit_softcap,
        backend == "pallas-interpret",
    )


def _sdpa_small(q, k, v, bias, cfg):
    """Unblocked attention for decode (Sq == 1) and tiny test shapes.
    q:(B,Sq,H,hd) k,v:(B,Sk,K,hd); bias is PER-BATCH-ROW, broadcast into the
    scores as ``bias[:, None, None]`` — so it must be (B, Sq, Sk) or any
    right-aligned prefix-broadcastable shape like the engine's (B, 1, Sk)
    (every slot sits at its own position, hence its own mask row)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    q = q.reshape(b, sq, kh, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q * scale, k).astype(jnp.float32)
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = scores + bias[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, h, hd)


def attn_train(params, x, cfg, positions=None):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)
    out = _attn_mix(q, k, v, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return constrain(out, "batch", None, None)


# ---------------------------------------------------------------------------
# KV cache


def cache_len(cfg, max_seq: int) -> int:
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, max_seq)
    return max_seq


def init_cache(cfg, batch: int, max_seq: int, dtype) -> Dict[str, jax.Array]:
    s = cache_len(cfg, max_seq)
    kv, hd = cfg.num_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, s, kv, hd), dtype),
        "v": jnp.zeros((batch, s, kv, hd), dtype),
    }


def init_paged_cache(cfg, n_pages: int, page_size: int, dtype) -> Dict[str, jax.Array]:
    """Paged decode cache: a pool of fixed-size pages SHARED by all slots
    (repro.serve.kv_pool.KVPool hands out page ids; the per-slot page table
    lives in the engine's DecodeState). HBM is ``n_pages × page_size`` — the
    allocated-token footprint — instead of the dense ``slots × cache_len``."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim_
    return {
        "k_pages": jnp.zeros((n_pages, page_size, kv, hd), dtype),
        "v_pages": jnp.zeros((n_pages, page_size, kv, hd), dtype),
    }


def attn_prefill(params, x, cfg, cache):
    """Full-sequence attention that also fills the cache.

    The cache keeps its allocated length ``cl`` (which may exceed the prompt
    — decode continues into the tail; returning a prompt-length cache was a
    silent decode-corruption bug caught by
    tests/test_models_property.py::test_decode_matches_full_forward). For
    sliding-window layers whose prompt exceeds the ring length, the kept
    tail lands on its ring slots (slot = position % cl) so
    :func:`attn_decode`'s position reconstruction stays consistent."""
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)
    out = _attn_mix(q, k, v, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    cl = cache["k"].shape[1]
    if s < cl:
        new_k = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    else:
        tail_pos = jnp.arange(s - cl, s, dtype=jnp.int32)
        slots = tail_pos % cl if cfg.sliding_window > 0 else jnp.arange(cl, dtype=jnp.int32)
        new_k = jnp.zeros_like(cache["k"]).at[:, slots].set(k[:, -cl:].astype(cache["k"].dtype))
        new_v = jnp.zeros_like(cache["v"]).at[:, slots].set(v[:, -cl:].astype(cache["v"].dtype))
    return constrain(out, "batch", None, None), {"k": new_k, "v": new_v}


def attn_decode(params, x, cfg, cache, pos, page_table=None):
    """One-token decode. x: (B, 1, d); pos: scalar int32 — the index of this
    token — or an (B,) int32 vector of per-row positions (the continuous-
    batching engine decodes slots sitting at different depths in one step).

    Two cache layouts: the dense per-slot cache ({"k", "v"}, may be a ring
    buffer for SWA) attends via the small SDPA path; a PAGED cache
    ({"k_pages", "v_pages"} from :func:`init_paged_cache`, plus the engine's
    ``page_table``) takes the page-table view — the new K/V land on the
    write position's page and attention runs through the flash-decode kernel
    dispatch (``cfg.decode_backend``). Both layouts use identical ring/mask
    math, so they are token-for-token interchangeable."""
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    posv = jnp.broadcast_to(pos.reshape(-1), (b,)) if pos.ndim else jnp.full((b,), pos)
    q, k_new, v_new = _project_qkv(params, x, cfg, posv[:, None])
    if "k_pages" in cache:
        return _attn_decode_paged(params, q, k_new, v_new, cfg, cache, posv, page_table, x)
    cl = cache["k"].shape[1]
    if cfg.sliding_window > 0 and cl < 2**31:
        slot = posv % cl
    else:
        slot = jnp.minimum(posv, cl - 1)
    rows = jnp.arange(b, dtype=jnp.int32)
    k = cache["k"].at[rows, slot].set(k_new[:, 0])
    v = cache["v"].at[rows, slot].set(v_new[:, 0])
    k = constrain(k, "batch", "seq", None, None)
    v = constrain(v, "batch", "seq", None, None)
    # absolute position of every cache slot, per row
    ring_idx = jnp.arange(cl, dtype=jnp.int32)[None, :]  # (1, cl)
    p = posv[:, None]  # (B, 1)
    if cfg.sliding_window > 0:
        wrap = (p // cl) * cl
        k_pos = jnp.where(ring_idx <= slot[:, None], wrap + ring_idx, wrap - cl + ring_idx)
        valid = (k_pos >= 0) & (k_pos <= p) & (k_pos > p - cfg.sliding_window)
    else:
        valid = ring_idx <= p
    bias = jnp.where(valid, 0.0, NEG_INF)[:, None, :]  # (B, 1, cl)
    out = _sdpa_small(q, k, v, bias, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return constrain(out, "batch", None, None), {"k": k, "v": v}


def attn_extend(params, x, cfg, cache, pos, page_table):
    """Multi-token continuation against an existing PAGED cache.

    x: (B, S, d); ``pos``: (B,) int32 per-row start positions — row ``b``'s
    tokens occupy logical positions ``pos[b] .. pos[b]+S-1``. This is the
    primitive behind prefix-cache admission (prefill only the uncovered tail
    after a page-table splice) and speculative verify (score k draft tokens
    in one forward): both need "prefill semantics, but starting mid-cache",
    which neither attn_prefill (always position 0) nor attn_decode (S == 1)
    provides.

    K/V are scattered into the pages FIRST and attended after (each query
    sees every position ≤ its own through the gathered table view), so pad
    tail positions — and draft tokens later rejected — hold garbage that was
    never attended by any surviving query and are simply overwritten by the
    next write at that position: the same write-before-attend invariant that
    makes bucketed-prefill pad tails and speculative rollback free.

    Ring (sliding-window) layouts are rejected: a wrapped write could land in
    a page-table entry another request shares (prefix cache) or that a
    rejected draft already dirtied at a DIFFERENT logical position — the
    engine gates SWA archs off this path entirely.

    Writes past the table extent (pad tails of a bucketed extend group) are
    redirected to the table's LAST page — the engine's scratch page by
    construction (``DecodeWorker`` sizes the device buffer one page past the
    pool and stale/unallocated table entries already point there)."""
    if cfg.sliding_window > 0:
        raise ValueError(
            "attn_extend requires a full (non-ring) cache: sliding-window "
            "layers wrap writes into shared/live pages"
        )
    b, s, _ = x.shape
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    idx = posv[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # (B, S)
    q, k_new, v_new = _project_qkv(params, x, cfg, idx)
    ps = cache["k_pages"].shape[1]
    extent = page_table.shape[1] * ps
    in_range = idx < extent
    pid = jnp.take_along_axis(page_table, jnp.minimum(idx // ps, page_table.shape[1] - 1), axis=1)
    pid = jnp.where(in_range, pid, cache["k_pages"].shape[0] - 1)
    off = idx % ps
    k_pages = cache["k_pages"].at[pid, off].set(k_new.astype(cache["k_pages"].dtype))
    v_pages = cache["v_pages"].at[pid, off].set(v_new.astype(cache["v_pages"].dtype))
    # gather the logical cache through the table and attend densely — extend
    # runs at admission/verify cadence, not per token; a fused gather kernel
    # (flash_decode's big sibling) is future work.
    k_full = k_pages[page_table].reshape(b, extent, *k_pages.shape[2:])
    v_full = v_pages[page_table].reshape(b, extent, *v_pages.shape[2:])
    valid = jnp.arange(extent, dtype=jnp.int32)[None, None, :] <= idx[:, :, None]
    bias = jnp.where(valid, 0.0, NEG_INF)  # (B, S, extent)
    out = _sdpa_small(q, k_full, v_full, bias, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return constrain(out, "batch", None, None), {"k_pages": k_pages, "v_pages": v_pages}


def _attn_decode_paged(params, q, k_new, v_new, cfg, cache, posv, page_table, x):
    """Paged decode: scatter the new K/V onto the write position's page, then
    attend through the flash-decode dispatch. The logical index math (ring
    slot for SWA, absolute position otherwise) is the dense path's, just
    indirected through ``page_table``; the true logical cache length is
    recovered from the table extent W·ps — for full attention it IS max_seq
    (EngineConfig enforces ``max_seq % page_size == 0``), and an SWA ring of
    ``min(window, max_seq)`` slots satisfies cl <= W·ps < cl + ps, so
    ``min(window, W·ps)`` recovers cl exactly in every combination."""
    if page_table is None:
        raise ValueError("paged KV cache requires a page_table (see repro.serve.kv_pool)")
    from repro.kernels.flash_decode import flash_decode

    b = posv.shape[0]
    ps = cache["k_pages"].shape[1]
    extent = page_table.shape[1] * ps
    if cfg.sliding_window > 0:
        cl = min(cfg.sliding_window, extent)
        slot = posv % cl
    else:
        cl = extent
        slot = jnp.minimum(posv, cl - 1)
    rows = jnp.arange(b, dtype=jnp.int32)
    pid = page_table[rows, slot // ps]
    off = slot % ps
    k_pages = cache["k_pages"].at[pid, off].set(k_new[:, 0].astype(cache["k_pages"].dtype))
    v_pages = cache["v_pages"].at[pid, off].set(v_new[:, 0].astype(cache["v_pages"].dtype))
    out = flash_decode(
        q[:, 0], k_pages, v_pages, page_table, posv,
        window=cfg.sliding_window, softcap=cfg.attn_logit_softcap,
        cache_len=cl, backend=cfg.backend_for("decode"),
    )
    out = jnp.einsum("bhk,hkd->bd", out, params["wo"].astype(x.dtype))[:, None]
    return constrain(out, "batch", None, None), {"k_pages": k_pages, "v_pages": v_pages}

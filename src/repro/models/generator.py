"""Data-free synthesis generators.

``image_generator`` — the paper's generator (same family as DENSE / DAFL): a
label-conditional latent-to-image decoder (dense → 2× upsample conv stack →
tanh). Normalization is batch-stat instance/batch norm computed on the fly
(the generator is only ever run in training mode, so no running stats).

``embedding_generator`` — our LLM-distillation extension (DESIGN.md §5):
tokens are discrete, so for token models the generator synthesizes
*embedding-space* sequences (B, S, d_model) that are fed to the client
ensemble in place of embedded tokens. Losses (Eq. 5–12) are unchanged.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.cnn import _conv_init, _dense_init, conv2d


def _bn(x, scale, bias, eps=1e-5):
    """Batch norm over (B, H, W) with batch statistics (train-mode only)."""
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return x * (1 + scale) + bias


def _bn_params(c):
    return {"scale": jnp.zeros((c,)), "bias": jnp.zeros((c,))}


def _upsample2(x):
    b, h, w, c = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, None, :], (b, h, 2, w, 2, c))
    return x.reshape(b, 2 * h, 2 * w, c)


def init_image_generator(
    key, latent_dim: int, num_classes: int, out_shape: Tuple[int, int, int], base: int = 64
):
    h, w, c = out_shape
    assert h % 4 == 0 and w % 4 == 0, out_shape
    h0, w0 = h // 4, w // 4
    ks = jax.random.split(key, 6)
    return {
        "label_embed": (jax.random.normal(ks[0], (num_classes, latent_dim)) * 0.1),
        "fc": _dense_init(ks[1], 2 * latent_dim, h0 * w0 * 2 * base),
        "bn0": _bn_params(2 * base),
        "conv1": _conv_init(ks[2], 3, 2 * base, 2 * base),
        "bn1": _bn_params(2 * base),
        "conv2": _conv_init(ks[3], 3, 2 * base, base),
        "bn2": _bn_params(base),
        "conv3": _conv_init(ks[4], 3, base, c),
    }


def image_generator(params, z, y, out_shape: Tuple[int, int, int], base: int = 64):
    """z: (B, nz); y: (B,) int labels. Returns images in [-1, 1], NHWC."""
    h0, w0, c0 = out_shape[0] // 4, out_shape[1] // 4, 2 * base
    emb = params["label_embed"][y]
    x = jnp.concatenate([z, emb], axis=-1)
    x = (x @ params["fc"]).reshape(-1, h0, w0, c0)
    x = _bn(x, **params["bn0"])
    x = _upsample2(x)
    x = jax.nn.leaky_relu(_bn(conv2d(x, params["conv1"]), **params["bn1"]), 0.2)
    x = _upsample2(x)
    x = jax.nn.leaky_relu(_bn(conv2d(x, params["conv2"]), **params["bn2"]), 0.2)
    x = jnp.tanh(conv2d(x, params["conv3"]))
    return x


def init_embedding_generator(key, latent_dim: int, num_classes: int, seq_len: int, d_model: int, hidden: int = 256):
    ks = jax.random.split(key, 4)
    return {
        "label_embed": (jax.random.normal(ks[0], (num_classes, latent_dim)) * 0.1),
        "fc1": _dense_init(ks[1], 2 * latent_dim, hidden),
        "fc2": _dense_init(ks[2], hidden, seq_len * min(d_model, hidden)),
        "proj": _dense_init(ks[3], min(d_model, hidden), d_model),
    }


def embedding_generator(params, z, y, seq_len: int, hidden: int = 256):
    """z: (B, nz); y: (B,). Returns (B, S, d_model) synthetic embeddings."""
    s = seq_len
    dh = params["proj"].shape[0]
    emb = params["label_embed"][y]
    x = jnp.concatenate([z, emb], axis=-1)
    x = jax.nn.relu(x @ params["fc1"])
    x = (x @ params["fc2"]).reshape(-1, s, dh)
    x = jnp.tanh(x)
    return x @ params["proj"]

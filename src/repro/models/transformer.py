"""Unified decoder/encoder LM covering all six assigned architecture
families (dense, moe, ssm, hybrid, audio-encoder, vlm).

A model is a sequence of *blocks*; each block is ``(mixer, ffn)`` where
mixer ∈ {attn, mamba, mlstm, slstm} and ffn ∈ {mlp, moe, None}. The
per-layer pattern is derived from the config (``layer_kinds``) and has a
repeating period (``group_period``): dense/moe archs repeat every layer,
jamba every ``attn_every`` layers, xlstm every ``slstm_every``. Layers are
*stacked by group* so the forward pass is a single ``lax.scan`` over groups
(optionally rematerialized) — the HLO stays O(period) regardless of depth,
which keeps the 94-layer qwen3-moe dry-run compilable.

Inputs are a ``batch`` dict:
  * tokens:  (B, S) int32                      — LM text stream
  * frames:  (B, S, frontend_dim)              — audio family (stub frontend)
  * prefix:  (B, P, frontend_dim)              — vlm patch embeddings (stub)
  * labels:  same shape as tokens/frames' time axis

Decode state is a per-group stack of per-position mixer states (KV cache /
SSM state), so decode is the same single scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import apply_mlp, dense_init, embed_init, init_mlp, rms_norm
from repro.sharding import constrain
from repro.utils import tree_stack

# ---------------------------------------------------------------------------
# layer pattern


def layer_kinds(cfg) -> List[Tuple[str, Optional[str]]]:
    """Per-layer (mixer, ffn) pattern for the whole network."""
    kinds: List[Tuple[str, Optional[str]]] = []
    for i in range(cfg.num_layers):
        if cfg.family == "ssm":
            if cfg.ssm_kind == "mamba":
                kinds.append(("mamba", None))
            else:  # xlstm: sLSTM every `slstm_every`, rest mLSTM
                if cfg.slstm_every and i % cfg.slstm_every == cfg.slstm_every - 1:
                    kinds.append(("slstm", None))
                else:
                    kinds.append(("mlstm", None))
        elif cfg.family == "hybrid":
            mixer = "attn" if i % cfg.attn_every == cfg.attn_every // 2 else "mamba"
            ffn = (
                "moe"
                if cfg.moe_every and i % cfg.moe_every == cfg.moe_every - 1 and cfg.num_experts
                else "mlp"
            )
            kinds.append((mixer, ffn))
        elif cfg.family == "moe":
            kinds.append(("attn", "moe"))
        else:  # dense, audio, vlm
            kinds.append(("attn", "mlp"))
    return kinds


def group_period(cfg) -> int:
    if cfg.family == "hybrid":
        period = cfg.attn_every
        if cfg.moe_every:
            while period % cfg.moe_every:
                period += cfg.attn_every
        return period
    if cfg.family == "ssm" and cfg.ssm_kind == "xlstm" and cfg.slstm_every:
        return cfg.slstm_every
    return 1


def group_pattern(cfg) -> List[Tuple[str, Optional[str]]]:
    return layer_kinds(cfg)[: group_period(cfg)]


def num_groups(cfg) -> int:
    p = group_period(cfg)
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    return cfg.num_layers // p


# ---------------------------------------------------------------------------
# block init / apply

_MIXER_INIT = {
    "attn": attn_lib.init_attention,
    "mamba": mamba_lib.init_mamba,
    "mlstm": xlstm_lib.init_mlstm,
    "slstm": xlstm_lib.init_slstm,
}

_MIXER_KEY = {"attn": "attn", "mamba": "mamba", "mlstm": "xlstm", "slstm": "xlstm"}


def _init_block(key, cfg, mixer: str, ffn: Optional[str], dtype):
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {
        _MIXER_KEY[mixer]: _MIXER_INIT[mixer](k1, cfg, dtype),
        "norm1": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }
    if ffn == "mlp":
        p["mlp"] = init_mlp(k2, cfg, dtype)
        p["norm2"] = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    elif ffn == "moe":
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
        p["norm2"] = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    return p


def _apply_mixer(p, x, cfg, mixer, mode, state, pos, page_table=None):
    """mode: train | prefill | decode | extend. Returns (y, new_state)."""
    if mixer == "attn":
        if mode == "train":
            return attn_lib.attn_train(p["attn"], x, cfg), None
        if mode == "prefill":
            return attn_lib.attn_prefill(p["attn"], x, cfg, state)
        if mode == "extend":
            return attn_lib.attn_extend(p["attn"], x, cfg, state, pos, page_table)
        return attn_lib.attn_decode(p["attn"], x, cfg, state, pos, page_table=page_table)
    if mode == "extend":
        # a recurrent carry has no per-position cache to continue from: the
        # whole point of extend (start mid-sequence, roll back rejected
        # positions for free) is attention-cache-shaped. The engine gates
        # ssm/hybrid archs off the prefix-cache/spec-decode paths.
        raise ValueError(f"extend mode requires attention mixers, got {mixer!r}")
    if mixer == "mamba":
        if mode == "train":
            return mamba_lib.mamba_forward(p["mamba"], x, cfg), None
        return mamba_lib.mamba_forward(
            p["mamba"], x, cfg, state=state if mode == "decode" else None, return_state=True
        )
    fwd = xlstm_lib.mlstm_forward if mixer == "mlstm" else xlstm_lib.slstm_forward
    if mode == "train":
        return fwd(p["xlstm"], x, cfg), None
    return fwd(p["xlstm"], x, cfg, state=state if mode == "decode" else None, return_state=True)


def _apply_block(p, x, cfg, mixer, ffn, mode, state, pos, page_table=None):
    h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    y, new_state = _apply_mixer(p, h, cfg, mixer, mode, state, pos, page_table)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ffn == "mlp":
        x = x + apply_mlp(p["mlp"], rms_norm(x, p["norm2"]["scale"], cfg.norm_eps), cfg)
    elif ffn == "moe":
        y, aux = moe_lib.moe_apply(p["moe"], rms_norm(x, p["norm2"]["scale"], cfg.norm_eps), cfg)
        x = x + y
    return x, aux, new_state


# ---------------------------------------------------------------------------
# model init


def init_lm(cfg, key, param_dtype=None):
    dtype = jnp.dtype(param_dtype or cfg.param_dtype)
    pattern = group_pattern(cfg)
    g = num_groups(cfg)
    keys = jax.random.split(key, g + 3)

    def one_group(k):
        ks = jax.random.split(k, len(pattern))
        return {
            f"p{i}": _init_block(ks[i], cfg, mixer, ffn, dtype)
            for i, (mixer, ffn) in enumerate(pattern)
        }

    groups = tree_stack([one_group(keys[i]) for i in range(g)])
    params: Dict[str, Any] = {
        "groups": groups,
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }
    if cfg.is_encoder_only:
        params["frames_proj"] = {"projector": {"kernel": dense_init(keys[g], cfg.frontend_dim, (cfg.d_model,), dtype)}}
        params["pred_head"] = {"kernel": dense_init(keys[g + 1], cfg.d_model, (cfg.vocab_size,), dtype)}
    else:
        params["embed"] = {"table": embed_init(keys[g], cfg.vocab_size, cfg.d_model, dtype)}
        if not cfg.tie_embeddings:
            params["lm_head"] = {"kernel": dense_init(keys[g + 1], cfg.d_model, (cfg.vocab_size,), dtype)}
    if cfg.frontend == "vision":
        params["prefix_proj"] = {"projector": {"kernel": dense_init(keys[g + 2], cfg.frontend_dim, (cfg.d_model,), dtype)}}
    return params


# ---------------------------------------------------------------------------
# embedding / head


def _embed_inputs(params, cfg, batch) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    if "embeds" in batch:
        # synthetic embedding-space inputs (the LM-scale Co-Boosting
        # generator path, DESIGN.md §5) — bypass the token embedding.
        return constrain(batch["embeds"].astype(dtype), "batch", "seq", None)
    if cfg.is_encoder_only:
        x = jnp.einsum(
            "bsf,fd->bsd", batch["frames"].astype(dtype), params["frames_proj"]["projector"]["kernel"].astype(dtype)
        )
    else:
        x = params["embed"]["table"].astype(dtype)[batch["tokens"]]
        if cfg.frontend == "vision" and "prefix" in batch:
            pre = jnp.einsum(
                "bpf,fd->bpd",
                batch["prefix"].astype(dtype),
                params["prefix_proj"]["projector"]["kernel"].astype(dtype),
            )
            x = jnp.concatenate([pre, x], axis=1)
    return constrain(x, "batch", "seq", None)


def head_matrix(params, cfg) -> jax.Array:
    """The (d, V) output-projection matrix."""
    if cfg.is_encoder_only:
        return params["pred_head"]["kernel"]
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["kernel"]


def lm_logits(params, cfg, x) -> jax.Array:
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    w = head_matrix(params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype)).astype(jnp.dtype(cfg.logit_dtype))
    # Shard the (B, S, V) logits over the model axis by vocab when it
    # divides, else by SEQUENCE. granite's odd 49155 vocab cannot shard a
    # 16-wide axis; without the fallback the full logits replicate on every
    # model-axis device (measured: 12 GiB/device f32 buffers ×17, 29 GiB
    # temp — the entire HBM overrun of the granite train dry-run).
    from repro.sharding.partition import _mesh_axes

    axes = _mesh_axes()
    model = axes.get("model", 1)
    if model > 1 and cfg.vocab_size % model and logits.shape[1] > 1:
        return constrain(logits, "batch", "seq", None)
    return constrain(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# forward (train)


def _scan_blocks(params, cfg, x, mode: str, state=None, pos=None, page_table=None):
    """Run all groups. Returns (x, aux_sum, new_state_stack_or_None).
    ``page_table`` (paged decode only) is loop-invariant: it rides into the
    scan body as a closure constant, not a scanned leaf."""
    pattern = group_pattern(cfg)

    def body(x, inp):
        gp, st = inp
        aux_total = jnp.zeros((), jnp.float32)
        new_st = {}
        for i, (mixer, ffn) in enumerate(pattern):
            s_i = None if st is None else st.get(f"p{i}")
            x, aux, ns = _apply_block(gp[f"p{i}"], x, cfg, mixer, ffn, mode, s_i, pos, page_table)
            aux_total = aux_total + aux
            if ns is not None:
                new_st[f"p{i}"] = ns
        return x, (aux_total, new_st)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    groups = params["groups"]
    g = num_groups(cfg)
    if cfg.scan_layers:
        if state is None:
            x, (auxs, _) = jax.lax.scan(lambda c, gp: body(c, (gp, None)), x, groups)
            return x, jnp.sum(auxs), None
        x, (auxs, new_states) = jax.lax.scan(body, x, (groups, state))
        return x, jnp.sum(auxs), new_states
    # unrolled (smoke tests)
    from repro.utils import tree_index

    aux_total = jnp.zeros((), jnp.float32)
    new_states = []
    for gi in range(g):
        gp = tree_index(groups, gi)
        st = None if state is None else tree_index(state, gi)
        x, (aux, ns) = body(x, (gp, st))
        aux_total = aux_total + aux
        new_states.append(ns)
    stacked = tree_stack(new_states) if state is not None else None
    return x, aux_total, stacked


def lm_forward(params, cfg, batch) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, moe_aux_loss)."""
    x = _embed_inputs(params, cfg, batch)
    x, aux, _ = _scan_blocks(params, cfg, x, "train")
    return lm_logits(params, cfg, x), aux


def lm_features(params, cfg, batch) -> Tuple[jax.Array, jax.Array]:
    """Post-final-norm trunk features (B, S, d) — the LM head factored out
    so vocab-sized tensors can be produced chunk-by-chunk (distillation
    memory lever, core.distributed.coboost_distill_loss kl_chunk)."""
    x = _embed_inputs(params, cfg, batch)
    x, aux, _ = _scan_blocks(params, cfg, x, "train")
    return rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps), aux


def cross_entropy(logits, labels, mask=None):
    """logits: (B,S,V) any float dtype; labels: (B,S) int32.

    The label logit is picked with an iota-compare + masked sum rather than
    ``take_along_axis``: a gather along the vocab-sharded axis forces the
    SPMD partitioner to all-gather the full (B,S,V) logits (observed 13 GB/
    device at 152k vocab), whereas the compare/sum form stays elementwise
    and inherits the ("batch", None, "vocab") sharding."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    hit = (
        jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        == labels[..., None]
    )
    ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(params, cfg, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = lm_forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "prefix" in batch:
        logits = logits[:, batch["prefix"].shape[1] :]  # loss on text positions only
    loss = cross_entropy(logits, labels, batch.get("mask"))
    total = loss + cfg.router_aux_coef * aux
    return total, {"ce": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# decode state


def _init_mixer_state(cfg, mixer: str, batch: int, max_seq: int, dtype, kv_pages=0, kv_page_size=0):
    if mixer == "attn":
        if kv_pages > 0:
            return attn_lib.init_paged_cache(cfg, kv_pages, kv_page_size, dtype)
        return attn_lib.init_cache(cfg, batch, max_seq, dtype)
    if mixer == "mamba":
        return mamba_lib.init_mamba_state(cfg, batch, dtype)
    if mixer == "mlstm":
        return xlstm_lib.init_mlstm_state(cfg, batch)
    return xlstm_lib.init_slstm_state(cfg, batch)


def init_lm_state(cfg, batch: int, max_seq: int, dtype=None, *, kv_pages=0, kv_page_size=0):
    """Per-group stacked mixer states (the KV-cache / SSM-state pytree).

    ``kv_pages > 0`` swaps every attention cache for a shared page pool of
    that many ``kv_page_size``-token pages (the serve engine's paged layout;
    recurrent SSM/xLSTM states are O(1) per slot and stay per-slot dense).
    Decode then needs the engine's page table: ``lm_decode(..., page_table)``."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    pattern = group_pattern(cfg)
    g = num_groups(cfg)
    one = {
        f"p{i}": _init_mixer_state(cfg, mixer, batch, max_seq, dtype, kv_pages, kv_page_size)
        for i, (mixer, _) in enumerate(pattern)
    }
    return tree_stack([one] * g)


def shard_lm_state(state):
    """Apply the decode-state sharding constraints (KV cache seq-sharded)."""

    def f(path, x):
        # exact-suffix match: the paged pool leaves (/k_pages, /v_pages) have
        # no batch dim and must NOT pick up the dense (G,B,S,K,hd) constraint
        if x.ndim == 5 and (path.endswith("/k") or path.endswith("/v")):
            from repro.sharding import logical_to_pspec

            return jax.lax.with_sharding_constraint(
                x, logical_to_pspec((None, "batch", "seq", None, None), x.shape)
            )
        return x

    from repro.utils import tree_map_with_path

    return tree_map_with_path(f, state)


def lm_prefill(params, cfg, batch, state, last_index=None):
    """Consume the full prompt, fill the state, return last-position logits.

    ``last_index`` (int32 scalar or per-row (B,) vector, static or traced)
    selects which position's logits come back — the serving engine pads
    ragged prompts up to a bucket length and needs the logits of each row's
    TRUE last prompt token, not the pad tail. ``None`` keeps the legacy
    "last position" behavior."""
    x = _embed_inputs(params, cfg, batch)
    x, aux, new_state = _scan_blocks(params, cfg, x, "prefill", state=state)
    if last_index is None:
        x_last = x[:, -1:]
    else:
        idx = jnp.broadcast_to(jnp.asarray(last_index, jnp.int32).reshape(-1), (x.shape[0],))
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    logits = lm_logits(params, cfg, x_last)
    return logits, new_state


def lm_extend(params, cfg, tokens, state, pos, page_table):
    """Multi-token continuation against an existing PAGED state: consume
    ``tokens`` (B, S) starting at per-row positions ``pos`` (B,) and return
    the logits of EVERY fed position ((B, S, V)) plus the updated state.

    This is the third point on the prefill↔decode line: prefill consumes a
    whole prompt at position 0, decode consumes one token mid-cache, extend
    consumes a short run mid-cache — the primitive behind prefix-cache tail
    prefill (only the tokens the radix splice didn't cover) and speculative
    verify (score all k draft tokens in one dispatch). All-position logits
    come back because verify needs each draft's predecessor logits; tail
    prefill just takes its true-last row."""
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"]["table"].astype(dtype)[tokens]
    x = constrain(x, "batch", None, None)
    x, aux, new_state = _scan_blocks(
        params, cfg, x, "extend", state=state, pos=pos, page_table=page_table
    )
    logits = lm_logits(params, cfg, x)
    return logits, new_state


def lm_decode(params, cfg, token, state, pos, page_table=None):
    """One decode step. token: (B, 1) int32; pos: scalar int32 (absolute) or
    (B,) per-row positions. ``page_table`` ((B, W) int32) switches paged
    states (``init_lm_state(kv_pages=...)``) onto the page-table view."""
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"]["table"].astype(dtype)[token]
    x = constrain(x, "batch", None, None)
    x, aux, new_state = _scan_blocks(
        params, cfg, x, "decode", state=state, pos=pos, page_table=page_table
    )
    logits = lm_logits(params, cfg, x)
    return logits, new_state

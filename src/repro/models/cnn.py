"""The paper's client model zoo: small image classifiers.

These are the architectures Co-Boosting's own experiments ensemble over —
LeNet-5 (MNIST/FMNIST), the 5-layer CNN of McMahan et al. (SVHN/CIFAR), the
PyTorch-tutorial CNN, a small residual net, and an MLP. They are the
*heterogeneous client* zoo of Table 3.

All models share one functional interface:

    params = init_cnn(key, arch, num_classes, in_shape)
    logits = cnn_apply(params, images)            # images: (B, H, W, C)

Normalization is GroupNorm (stateless) rather than BatchNorm so that client
models are pure functions of (params, x) — no running-stat state to
transport through the one-shot upload. Documented deviation; the paper's
qualitative claims do not depend on the norm flavor.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

CNN_ARCHS = ("lenet5", "cnn5", "cnn2", "miniresnet", "mlp")


def _conv_init(key, k, cin, cout, dtype=jnp.float32):
    fan_in = k * k * cin
    std = jnp.sqrt(2.0 / fan_in)
    return (jax.random.normal(key, (k, k, cin, cout)) * std).astype(dtype)


def _dense_init(key, din, dout, dtype=jnp.float32):
    std = jnp.sqrt(2.0 / din)
    return (jax.random.normal(key, (din, dout)) * std).astype(dtype)


def conv2d(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding, dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def max_pool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
    )


def avg_pool_global(x):
    return jnp.mean(x, axis=(1, 2))


def group_norm(x, scale, bias, groups=8, eps=1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g).astype(jnp.float32)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    x = xg.reshape(b, h, w, c)
    return (x * (1 + scale) + bias).astype(x.dtype)


def _gn_params(c):
    return {"scale": jnp.zeros((c,)), "bias": jnp.zeros((c,))}


# ---------------------------------------------------------------------------
# architectures


def _init_lenet5(key, num_classes, in_shape):
    h, w, c = in_shape
    ks = jax.random.split(key, 5)
    fh, fw = h // 4, w // 4  # two 2x2 pools
    return {
        "c1": _conv_init(ks[0], 5, c, 6),
        "c2": _conv_init(ks[1], 5, 6, 16),
        "f1": _dense_init(ks[2], fh * fw * 16, 120),
        "f2": _dense_init(ks[3], 120, 84),
        "out": _dense_init(ks[4], 84, num_classes),
    }


def _apply_lenet5(p, x):
    x = jnp.tanh(conv2d(x, p["c1"]))
    x = max_pool(x)
    x = jnp.tanh(conv2d(x, p["c2"]))
    x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jnp.tanh(x @ p["f1"])
    x = jnp.tanh(x @ p["f2"])
    return x @ p["out"]


def _init_cnn5(key, num_classes, in_shape):
    """McMahan et al. 5-layer CNN: 2 conv + 3 fc."""
    h, w, c = in_shape
    ks = jax.random.split(key, 5)
    fh, fw = h // 4, w // 4
    return {
        "c1": _conv_init(ks[0], 5, c, 32),
        "c2": _conv_init(ks[1], 5, 32, 64),
        "f1": _dense_init(ks[2], fh * fw * 64, 512),
        "f2": _dense_init(ks[3], 512, 128),
        "out": _dense_init(ks[4], 128, num_classes),
    }


def _apply_cnn5(p, x):
    x = jax.nn.relu(conv2d(x, p["c1"]))
    x = max_pool(x)
    x = jax.nn.relu(conv2d(x, p["c2"]))
    x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["f1"])
    x = jax.nn.relu(x @ p["f2"])
    return x @ p["out"]


def _init_cnn2(key, num_classes, in_shape):
    """PyTorch-tutorial CNN: conv6/conv16 + 3 fc."""
    h, w, c = in_shape
    ks = jax.random.split(key, 5)
    fh, fw = h // 4, w // 4
    return {
        "c1": _conv_init(ks[0], 5, c, 6),
        "c2": _conv_init(ks[1], 5, 6, 16),
        "f1": _dense_init(ks[2], fh * fw * 16, 120),
        "f2": _dense_init(ks[3], 120, 84),
        "out": _dense_init(ks[4], 84, num_classes),
    }


def _apply_cnn2(p, x):
    x = jax.nn.relu(conv2d(x, p["c1"]))
    x = max_pool(x)
    x = jax.nn.relu(conv2d(x, p["c2"]))
    x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["f1"])
    x = jax.nn.relu(x @ p["f2"])
    return x @ p["out"]


def _init_resblock(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {
        "c1": _conv_init(ks[0], 3, cin, cout),
        "n1": _gn_params(cout),
        "c2": _conv_init(ks[1], 3, cout, cout),
        "n2": _gn_params(cout),
        "stride": stride,
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], 1, cin, cout)
    return p


def _apply_resblock(p, x):
    s = p["stride"]
    h = jax.nn.relu(group_norm(conv2d(x, p["c1"], stride=s), **p["n1"]))
    h = group_norm(conv2d(h, p["c2"]), **p["n2"])
    sc = conv2d(x, p["proj"], stride=s) if "proj" in p else x
    return jax.nn.relu(h + sc)


def _init_miniresnet(key, num_classes, in_shape):
    _, _, c = in_shape
    ks = jax.random.split(key, 6)
    return {
        "stem": _conv_init(ks[0], 3, c, 32),
        "stem_n": _gn_params(32),
        "b1": _init_resblock(ks[1], 32, 32, 1),
        "b2": _init_resblock(ks[2], 32, 64, 2),
        "b3": _init_resblock(ks[3], 64, 128, 2),
        "out": _dense_init(ks[4], 128, num_classes),
    }


def _apply_miniresnet(p, x):
    x = jax.nn.relu(group_norm(conv2d(x, p["stem"]), **p["stem_n"]))
    x = _apply_resblock(p["b1"], x)
    x = _apply_resblock(p["b2"], x)
    x = _apply_resblock(p["b3"], x)
    return avg_pool_global(x) @ p["out"]


def _init_mlp(key, num_classes, in_shape):
    h, w, c = in_shape
    ks = jax.random.split(key, 3)
    return {
        "f1": _dense_init(ks[0], h * w * c, 256),
        "f2": _dense_init(ks[1], 256, 128),
        "out": _dense_init(ks[2], 128, num_classes),
    }


def _apply_mlp(p, x):
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["f1"])
    x = jax.nn.relu(x @ p["f2"])
    return x @ p["out"]


_ARCHS = {
    "lenet5": (_init_lenet5, _apply_lenet5),
    "cnn5": (_init_cnn5, _apply_cnn5),
    "cnn2": (_init_cnn2, _apply_cnn2),
    "miniresnet": (_init_miniresnet, _apply_miniresnet),
    "mlp": (_init_mlp, _apply_mlp),
}


def init_cnn(key, arch: str, num_classes: int, in_shape: Tuple[int, int, int]):
    init, _ = _ARCHS[arch]
    return init(key, num_classes, in_shape)


def cnn_apply(arch: str, params, x):
    _, apply = _ARCHS[arch]
    return apply(params, x)


def make_cnn(arch: str, num_classes: int, in_shape: Tuple[int, int, int]):
    """Returns (init_fn(key) -> params, apply_fn(params, images) -> logits)."""
    init, apply = _ARCHS[arch]
    return partial(init, num_classes=num_classes, in_shape=in_shape), apply

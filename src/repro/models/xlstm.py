"""xLSTM blocks: mLSTM (matrix memory, stabilized exponential gating) and
sLSTM (scalar memory with hidden-to-hidden recurrence), per arXiv:2405.04517.

Both cells are true recurrences; we express them as ``lax.scan`` over time.
The mLSTM scan carries the per-head matrix state (C: hd×hd, n: hd, m: scalar)
and the sLSTM scan carries (h, c, n, m). On TPU the scan lowers to a single
while-loop HLO whose body is a batch of small MXU matmuls — sequential in
time but O(1) memory in sequence length, which is exactly why the ssm family
is the one that serves the 500k-token decode shape.

Decode reuses the same cell functions one step at a time.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding import constrain
from repro.utils.scan import chunked_scan


# ---------------------------------------------------------------------------
# mLSTM


def init_mlstm(key, cfg, dtype):
    d = cfg.d_model
    inner = cfg.ssm_inner
    h = cfg.xlstm_heads
    hd = inner // h
    keys = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(keys[0], d, (2 * inner,), dtype),
        "wq": dense_init(keys[1], inner, (h, hd), dtype),
        "wk": dense_init(keys[2], inner, (h, hd), dtype),
        "wv": dense_init(keys[3], inner, (h, hd), dtype),
        "gates": dense_init(keys[4], inner, (2 * h,), jnp.float32),  # i, f pre-acts
        "out_proj": dense_init(keys[5], inner, (d,), dtype),
    }


def _mlstm_cell(carry, qkvif):
    """One time step, vectorized over (B, H).

    carry: C (B,H,hd,hd), n (B,H,hd), m (B,H).
    qkvif: q,k,v (B,H,hd) f32; i_pre, f_pre (B,H) f32."""
    c, n, m = carry
    q, k, v, i_pre, f_pre = qkvif
    log_f = -jax.nn.softplus(-f_pre)  # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g[..., None, None] * c + i_g[..., None, None] * (v[..., :, None] * k[..., None, :])
    n_new = f_g[..., None] * n + i_g[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)), jnp.exp(-m_new))
    h_out = jnp.einsum("bhde,bhe->bhd", c_new, q) / denom[..., None]
    return (c_new, n_new, m_new), h_out


def mlstm_forward(params, x, cfg, state: Dict = None, return_state: bool = False):
    b, s, d = x.shape
    h, inner = cfg.xlstm_heads, cfg.ssm_inner
    hd = inner // h
    xz = jnp.einsum("bsd,di->bsi", x, params["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, "batch", None, "tp")
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    q = jnp.einsum("bsi,ihd->bshd", xin, params["wq"].astype(x.dtype)).astype(jnp.float32) * scale
    k = jnp.einsum("bsi,ihd->bshd", xin, params["wk"].astype(x.dtype)).astype(jnp.float32) * scale
    v = jnp.einsum("bsi,ihd->bshd", xin, params["wv"].astype(x.dtype)).astype(jnp.float32)
    gates = jnp.einsum("bsi,ig->bsg", xin.astype(jnp.float32), params["gates"])
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)  # (B,S,H)

    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.zeros((b, h), jnp.float32)
    else:
        c0, n0, m0 = state["C"], state["n"], state["m"]

    tfirst = lambda t: t.swapaxes(0, 1)  # (S, B, ...)
    # chunk-checkpointed: the carry C is (B,H,hd,hd) — per-step residuals
    # for 4k tokens would be tens of GB; chunking keeps O(S/chunk) carries.
    (cF, nF, mF), hs = chunked_scan(
        _mlstm_cell,
        (c0, n0, m0),
        (tfirst(q), tfirst(k), tfirst(v), tfirst(i_pre), tfirst(f_pre)),
        chunk=cfg.ssm_chunk,
    )
    hs = hs.swapaxes(0, 1).reshape(b, s, inner).astype(x.dtype)  # (B,S,H*hd)
    y = hs * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"].astype(x.dtype))
    out = constrain(out, "batch", None, None)
    if return_state:
        return out, {"C": cF, "n": nF, "m": mF}
    return out


def init_mlstm_state(cfg, batch: int) -> Dict:
    h = cfg.xlstm_heads
    hd = cfg.ssm_inner // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM


def init_slstm(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.xlstm_heads
    hd = d // h
    keys = jax.random.split(key, 3)
    return {
        # input projections for the 4 gates (z, i, f, o), fused
        "w": dense_init(keys[0], d, (4 * d,), dtype),
        # block-diagonal (per-head) hidden-to-hidden recurrence for the 4 gates
        "r": dense_init(keys[1], hd, (4, h, hd), jnp.float32, scale=0.5).transpose(1, 2, 0, 3),
        # (4, H, hd, hd)
        "out_proj": dense_init(keys[2], d, (d,), dtype),
    }


def _slstm_cell(params_r, carry, wx):
    """carry: h, c, n (B,H,hd), m (B,H). wx: (B, 4, H, hd) input pre-acts."""
    h, c, n, m = carry
    rec = jnp.einsum("ghde,bhe->bghd", params_r, h)  # (B,4,H,hd)
    pre = wx + rec
    z_pre, i_pre, f_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    log_f = -jax.nn.softplus(-f_pre)  # exponential-gate stabilized via m
    # per-head scalar stabilizer: track max over gate pre-acts
    i_max = jnp.max(i_pre, axis=-1)
    f_shift = jnp.max(log_f, axis=-1) + m
    m_new = jnp.maximum(f_shift, i_max)
    i_g = jnp.exp(i_pre - m_new[..., None])
    f_g = jnp.exp(log_f + (m - m_new)[..., None])
    c_new = f_g * c + i_g * jnp.tanh(z_pre)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_forward(params, x, cfg, state: Dict = None, return_state: bool = False):
    b, s, d = x.shape
    h = cfg.xlstm_heads
    hd = d // h
    wx = jnp.einsum("bsd,dg->bsg", x, params["w"].astype(x.dtype)).astype(jnp.float32)
    wx = wx.reshape(b, s, 4, h, hd)
    if state is None:
        zeros = jnp.zeros((b, h, hd), jnp.float32)
        carry = (zeros, zeros, zeros, jnp.zeros((b, h), jnp.float32))
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])
    cell = lambda cr, w_t: _slstm_cell(params["r"], cr, w_t)
    (hF, cF, nF, mF), hs = chunked_scan(cell, carry, wx.swapaxes(0, 1), chunk=cfg.ssm_chunk)
    hs = hs.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", hs, params["out_proj"].astype(x.dtype))
    out = constrain(out, "batch", None, None)
    if return_state:
        return out, {"h": hF, "c": cF, "n": nF, "m": mF}
    return out


def init_slstm_state(cfg, batch: int) -> Dict:
    h = cfg.xlstm_heads
    hd = cfg.d_model // h
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.zeros((batch, h), jnp.float32)}

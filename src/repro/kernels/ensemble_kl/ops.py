"""Differentiable public wrapper for the fused ensemble-KL kernel.

``backend`` (see :mod:`repro.kernels.dispatch`) selects the compiled Pallas
TPU kernel, the Pallas interpreter (debug/parity), or the pure-jnp reference.
The Pallas paths carry a ``jax.custom_vjp``: the forward kernel's online
softmax statistics (teacher/student logsumexp over the T-scaled logits) are
returned as residuals, and the backward pass is a recompute-based jnp VJP
that produces cotangents for ``client_logits``, ``student_logits`` and ``w``
— the student grad drives server distillation (Eq. 4) and the w grad feeds
the EE sign step (Eq. 12). Only the backward materializes A_w; the forward
hot path stays a single streamed pass.

With cotangent ``g`` per sample and ``t = A_w/T``, ``s = student/T``,
``p = softmax(t)``, ``q = softmax(s)``:

    ∂out/∂A_w      = T · p ⊙ (t − lse_t − s + lse_s − out/T²)
    ∂out/∂student  = T · (q − p)
    ∂out/∂w_k      = ⟨∂out/∂A_w, client_k⟩
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_backend
from repro.kernels.ensemble_kl.kernel import ensemble_kl_pallas
from repro.kernels.ensemble_kl.ref import ensemble_kl_ref


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _ensemble_kl_kernel(client_logits, student_logits, w, temperature, interpret, block_b, block_v):
    return ensemble_kl_pallas(
        client_logits, student_logits, w, temperature,
        block_b=block_b, block_v=block_v, interpret=interpret,
    )


def _ensemble_kl_fwd(client_logits, student_logits, w, temperature, interpret, block_b, block_v):
    out, lse_t, lse_s = ensemble_kl_pallas(
        client_logits, student_logits, w, temperature,
        block_b=block_b, block_v=block_v, interpret=interpret, return_stats=True,
    )
    return out, (client_logits, student_logits, w, out, lse_t, lse_s)


def _ensemble_kl_bwd(temperature, interpret, block_b, block_v, res, g):
    client_logits, student_logits, w, out, lse_t, lse_s = res
    temp = float(temperature)
    cl = client_logits.astype(jnp.float32)
    st = student_logits.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    t = jnp.einsum("k,kbv->bv", w32, cl) / temp
    s = st / temp
    p = jnp.exp(t - lse_t[:, None])
    q = jnp.exp(s - lse_s[:, None])
    kl_u = out / (temp * temp)  # unscaled KL, recovered from the primal out
    # d(out)/d(A_w) and d(out)/d(student): T² · dKL/d(t|s) · (1/T) = T · (…)
    g_ens = (g * temp)[:, None] * (p * ((t - lse_t[:, None]) - (s - lse_s[:, None]) - kl_u[:, None]))
    g_st = (g * temp)[:, None] * (q - p)
    g_cl = w32[:, None, None] * g_ens[None]
    g_w = jnp.einsum("bv,kbv->k", g_ens, cl)
    return (
        g_cl.astype(client_logits.dtype),
        g_st.astype(student_logits.dtype),
        g_w.astype(w.dtype),
    )


_ensemble_kl_kernel.defvjp(_ensemble_kl_fwd, _ensemble_kl_bwd)


@partial(jax.jit, static_argnames=("temperature", "backend", "block_b", "block_v"))
def ensemble_kl(
    client_logits: jax.Array,
    student_logits: jax.Array,
    w: jax.Array,
    temperature: float = 1.0,
    backend: str = "auto",
    block_b: int = 8,
    block_v: int = 512,
) -> jax.Array:
    """Per-sample KL(A_w ‖ student)·T². client_logits: (K, B, V)."""
    resolved = resolve_backend(backend)
    if resolved == "ref":
        return ensemble_kl_ref(client_logits, student_logits, w, temperature)
    return _ensemble_kl_kernel(
        client_logits, student_logits, w, float(temperature),
        resolved == "pallas-interpret", block_b, block_v,
    )

"""Differentiable public wrapper for the fused ensemble-KL kernel.

``backend`` (see :mod:`repro.kernels.dispatch`) selects the compiled Pallas
TPU kernel, the Pallas interpreter (debug/parity), or the pure-jnp reference
— and the choice covers BOTH passes: the Pallas paths carry a
``jax.custom_vjp`` whose forward returns the kernel's online softmax
statistics (teacher/student logsumexp over the T-scaled logits) as residuals
and whose backward is the fused Pallas kernel
:func:`repro.kernels.ensemble_kl.kernel.ensemble_kl_bwd_pallas`, producing
cotangents for ``client_logits``, ``student_logits`` and ``w`` in one
streamed (batch, vocab) sweep — the student grad drives server distillation
(Eq. 4) and the w grad feeds the EE sign step (Eq. 12). Neither pass ever
materializes A_w (or any K×(B,V) temporary) in HBM. ``backend="ref"``
bypasses the custom_vjp entirely: plain autodiff of the jnp oracle is the
parity baseline for the kernel backward.

With cotangent ``g`` per sample and ``t = A_w/T``, ``s = student/T``,
``p = softmax(t)``, ``q = softmax(s)``:

    ∂out/∂A_w      = T · p ⊙ (t − lse_t − s + lse_s − out/T²)
    ∂out/∂student  = T · (q − p)
    ∂out/∂w_k      = ⟨∂out/∂A_w, client_k⟩
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_backend
from repro.kernels.ensemble_kl.kernel import ensemble_kl_bwd_pallas, ensemble_kl_pallas
from repro.kernels.ensemble_kl.ref import ensemble_kl_ref


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _ensemble_kl_kernel(client_logits, student_logits, w, temperature, interpret, block_b, block_v):
    return ensemble_kl_pallas(
        client_logits, student_logits, w, temperature,
        block_b=block_b, block_v=block_v, interpret=interpret,
    )


def _ensemble_kl_fwd(client_logits, student_logits, w, temperature, interpret, block_b, block_v):
    out, lse_t, lse_s = ensemble_kl_pallas(
        client_logits, student_logits, w, temperature,
        block_b=block_b, block_v=block_v, interpret=interpret, return_stats=True,
    )
    return out, (client_logits, student_logits, w, out, lse_t, lse_s)


def _ensemble_kl_bwd(temperature, interpret, block_b, block_v, res, g):
    client_logits, student_logits, w, out, lse_t, lse_s = res
    return ensemble_kl_bwd_pallas(
        client_logits, student_logits, w, g, out, lse_t, lse_s,
        float(temperature), block_b=block_b, block_v=block_v, interpret=interpret,
    )


_ensemble_kl_kernel.defvjp(_ensemble_kl_fwd, _ensemble_kl_bwd)


@partial(jax.jit, static_argnames=("temperature", "backend", "block_b", "block_v"))
def ensemble_kl(
    client_logits: jax.Array,
    student_logits: jax.Array,
    w: jax.Array,
    temperature: float = 1.0,
    backend: str = "auto",
    block_b: int = 8,
    block_v: int = 512,
) -> jax.Array:
    """Per-sample KL(A_w ‖ student)·T². client_logits: (K, B, V)."""
    resolved = resolve_backend(backend)
    if resolved == "ref":
        return ensemble_kl_ref(client_logits, student_logits, w, temperature)
    return _ensemble_kl_kernel(
        client_logits, student_logits, w, float(temperature),
        resolved == "pallas-interpret", block_b, block_v,
    )

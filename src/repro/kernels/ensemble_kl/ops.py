"""Jitted public wrapper for the fused ensemble-KL kernel.

On CPU (this container) the Pallas body executes in interpret mode; on TPU
the same BlockSpecs tile VMEM. ``use_kernel=False`` falls back to the
pure-jnp reference (used by XLA-fusion comparison benchmarks).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ensemble_kl.kernel import ensemble_kl_pallas
from repro.kernels.ensemble_kl.ref import ensemble_kl_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("temperature", "use_kernel", "block_b", "block_v"))
def ensemble_kl(
    client_logits: jax.Array,
    student_logits: jax.Array,
    w: jax.Array,
    temperature: float = 1.0,
    use_kernel: bool = True,
    block_b: int = 8,
    block_v: int = 512,
) -> jax.Array:
    """Per-sample KL(A_w ‖ student)·T². client_logits: (K, B, V)."""
    if not use_kernel:
        return ensemble_kl_ref(client_logits, student_logits, w, temperature)
    return ensemble_kl_pallas(
        client_logits,
        student_logits,
        w,
        temperature,
        block_b=block_b,
        block_v=block_v,
        interpret=not _on_tpu(),
    )

"""Fused weighted-ensemble + temperature-KL Pallas TPU kernel.

Eq. 4 of the paper evaluates KL(A_w(x) ‖ f_S(x)) where A_w = Σ_k w_k·f_k is
the weighted client-logit ensemble. Materializing A_w for an LLM vocab
(e.g. 151,936) means an extra K×(B,V) + (B,V) HBM round-trip per step. This
kernel streams (K, bb, bv) client-logit tiles and (bb, bv) student tiles
through VMEM, combines them with w on the fly, and maintains *online*
softmax statistics so the KL per sample is produced in a single pass:

    KL·T² where  KL = N/D − (log D + m_t) + (log D_s + m_s)
    N  = Σ_v e^{t_v−m_t}·(t_v − s_v),  D = Σ_v e^{t_v−m_t}
    (t, s are the temperature-scaled teacher/student logits)

Grid: (batch_tiles, vocab_tiles); vocab is the minor (fastest) grid dim so
the five (bb,) accumulators live in VMEM scratch across a vocab sweep.
Blocks are (8·n, 128·m)-aligned for the VPU; the combine is a K-step fma,
not an MXU matmul — this kernel is memory-bound by design (the roofline win
is removing the A_w HBM materialization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import tile_padding

NEG = -1e30


def _kernel(w_ref, client_ref, student_ref, out_ref, lset_ref, lses_ref, mt_ref, dt_ref, nt_ref, ms_ref, ds_ref, *, temperature: float, num_vocab_tiles: int, vocab: int, block_v: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        mt_ref[...] = jnp.full_like(mt_ref, NEG)
        dt_ref[...] = jnp.zeros_like(dt_ref)
        nt_ref[...] = jnp.zeros_like(nt_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG)
        ds_ref[...] = jnp.zeros_like(ds_ref)

    w = w_ref[...]  # (K, 1) f32
    cl = client_ref[...].astype(jnp.float32)  # (K, bb, bv)
    t = jnp.sum(w[:, :, None] * cl, axis=0) / temperature  # (bb, bv)
    s = student_ref[...].astype(jnp.float32) / temperature

    # mask the padded vocab tail
    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, t.shape, 1)
    valid = col < vocab
    t = jnp.where(valid, t, NEG)
    s_for_lse = jnp.where(valid, s, NEG)
    diff = jnp.where(valid, t - s, 0.0)

    # online teacher stats
    mt_old = mt_ref[...]
    mt_new = jnp.maximum(mt_old, jnp.max(t, axis=-1, keepdims=True))
    corr_t = jnp.exp(mt_old - mt_new)
    p = jnp.exp(t - mt_new)
    dt_ref[...] = dt_ref[...] * corr_t + jnp.sum(p, axis=-1, keepdims=True)
    nt_ref[...] = nt_ref[...] * corr_t + jnp.sum(p * diff, axis=-1, keepdims=True)
    mt_ref[...] = mt_new

    # online student logsumexp
    ms_old = ms_ref[...]
    ms_new = jnp.maximum(ms_old, jnp.max(s_for_lse, axis=-1, keepdims=True))
    ds_ref[...] = ds_ref[...] * jnp.exp(ms_old - ms_new) + jnp.sum(
        jnp.exp(s_for_lse - ms_new), axis=-1, keepdims=True
    )
    ms_ref[...] = ms_new

    @pl.when(vi == num_vocab_tiles - 1)
    def _final():
        d = dt_ref[...]
        lse_t = jnp.log(d) + mt_ref[...]
        lse_s = jnp.log(ds_ref[...]) + ms_ref[...]
        kl = nt_ref[...] / d - lse_t + lse_s
        out_ref[...] = (kl * (temperature**2)).astype(out_ref.dtype)
        # the online-softmax statistics double as the VJP residuals
        lset_ref[...] = lse_t.astype(lset_ref.dtype)
        lses_ref[...] = lse_s.astype(lses_ref.dtype)


def _bwd_kernel(
    w_ref,
    client_ref,
    student_ref,
    g_ref,
    out_ref,
    lset_ref,
    lses_ref,
    gcl_ref,
    gst_ref,
    gw_ref,
    *,
    temperature: float,
    vocab: int,
    block_v: int,
):
    """One (batch, vocab) tile of the Eq. 4 VJP (see ops.py for the math).

    Everything is recomputed tile-resident from the forward's online-softmax
    residuals: the weighted combine t = A_w/T is rebuilt from the streamed
    client tile (A_w itself never exists in HBM, same as the forward), p and
    q come from the saved logsumexps, and the three cotangents are emitted in
    the same sweep — g_cl and g_st tile-by-tile, g_w accumulated in a
    revisited (K, 1) output block that stays VMEM-resident across the whole
    grid (its index map is constant)."""
    bi = pl.program_id(0)
    vi = pl.program_id(1)

    @pl.when((bi == 0) & (vi == 0))
    def _init():
        gw_ref[...] = jnp.zeros_like(gw_ref)

    w = w_ref[...]  # (K, 1) f32
    cl = client_ref[...].astype(jnp.float32)  # (K, bb, bv)
    t = jnp.sum(w[:, :, None] * cl, axis=0) / temperature  # (bb, bv)
    s = student_ref[...].astype(jnp.float32) / temperature

    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, t.shape, 1)
    valid = col < vocab
    t = jnp.where(valid, t, NEG)
    s = jnp.where(valid, s, NEG)

    lse_t = lset_ref[...]  # (bb, 1)
    lse_s = lses_ref[...]
    g = g_ref[...]
    kl_u = out_ref[...] / (temperature * temperature)  # unscaled KL from the primal

    p = jnp.exp(t - lse_t)  # exact 0 on the padded vocab tail
    q = jnp.exp(s - lse_s)
    gT = g * temperature  # (bb, 1)
    g_ens = gT * (p * ((t - lse_t) - (s - lse_s) - kl_u))
    g_ens = jnp.where(valid, g_ens, 0.0)

    gcl_ref[...] = (w[:, :, None] * g_ens[None]).astype(gcl_ref.dtype)
    gst_ref[...] = (gT * (q - p)).astype(gst_ref.dtype)
    gw_ref[...] += jnp.sum(cl * g_ens[None], axis=(1, 2))[:, None]


def ensemble_kl_bwd_pallas(
    client_logits: jax.Array,
    student_logits: jax.Array,
    w: jax.Array,
    g: jax.Array,
    out: jax.Array,
    lse_t: jax.Array,
    lse_s: jax.Array,
    temperature: float = 1.0,
    *,
    block_b: int = 8,
    block_v: int = 512,
    interpret: bool = False,
):
    """Fused backward for :func:`ensemble_kl_pallas`.

    ``g`` is the per-sample cotangent (B,); ``out``/``lse_t``/``lse_s`` are
    the forward's primal output and online-softmax residuals. Returns
    ``(g_client, g_student, g_w)`` with the input dtypes — one streamed pass
    over the same (batch, vocab) grid as the forward, never materializing
    A_w (or any K×(B,V) f32 temporary beyond the cotangent itself)."""
    k, b, v = client_logits.shape
    block_b, block_v, pb, pv = tile_padding(b, v, block_b, block_v)
    if pb or pv:
        client_logits = jnp.pad(client_logits, ((0, 0), (0, pb), (0, pv)))
        student_logits = jnp.pad(student_logits, ((0, pb), (0, pv)))
    if pb:
        # padded rows carry a zero cotangent: every padded-row grad is zero
        g = jnp.pad(g, ((0, pb),))
        out = jnp.pad(out, ((0, pb),))
        lse_t = jnp.pad(lse_t, ((0, pb),))
        lse_s = jnp.pad(lse_s, ((0, pb),))
    bp, vp = b + pb, v + pv
    nb, nv = bp // block_b, vp // block_v

    row = lambda x: x.astype(jnp.float32).reshape(bp, 1)
    g_cl, g_st, g_w = pl.pallas_call(
        functools.partial(
            _bwd_kernel, temperature=float(temperature), vocab=v, block_v=block_v
        ),
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((k, 1), lambda bi, vi: (0, 0)),
            pl.BlockSpec((k, block_b, block_v), lambda bi, vi: (0, bi, vi)),
            pl.BlockSpec((block_b, block_v), lambda bi, vi: (bi, vi)),
            pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, block_b, block_v), lambda bi, vi: (0, bi, vi)),
            pl.BlockSpec((block_b, block_v), lambda bi, vi: (bi, vi)),
            pl.BlockSpec((k, 1), lambda bi, vi: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, bp, vp), client_logits.dtype),
            jax.ShapeDtypeStruct((bp, vp), student_logits.dtype),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        w.astype(jnp.float32).reshape(k, 1),
        client_logits,
        student_logits,
        row(g),
        row(out),
        row(lse_t),
        row(lse_s),
    )
    return g_cl[:, :b, :v], g_st[:b, :v], g_w[:, 0].astype(w.dtype)


def ensemble_kl_pallas(
    client_logits: jax.Array,
    student_logits: jax.Array,
    w: jax.Array,
    temperature: float = 1.0,
    *,
    block_b: int = 8,
    block_v: int = 512,
    interpret: bool = False,
    return_stats: bool = False,
):
    """client_logits: (K, B, V); student_logits: (B, V); w: (K,).
    Returns per-sample KL·T² of shape (B,); with ``return_stats=True`` also
    the teacher/student logsumexp over the T-scaled logits (the VJP
    residuals), each (B,).

    Tiles never shrink below the (8, 128) VPU alignment: short batches and
    narrow vocabs are zero-padded up to the block instead (padded rows are
    computed on benign zeros and sliced off; the padded vocab tail is masked
    inside the kernel)."""
    k, b, v = client_logits.shape
    block_b, block_v, pb, pv = tile_padding(b, v, block_b, block_v)
    if pb or pv:
        client_logits = jnp.pad(client_logits, ((0, 0), (0, pb), (0, pv)))
        student_logits = jnp.pad(student_logits, ((0, pb), (0, pv)))
    bp, vp = b + pb, v + pv
    nb, nv = bp // block_b, vp // block_v

    out, lse_t, lse_s = pl.pallas_call(
        functools.partial(
            _kernel,
            temperature=float(temperature),
            num_vocab_tiles=nv,
            vocab=v,
            block_v=block_v,
        ),
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((k, 1), lambda bi, vi: (0, 0)),
            pl.BlockSpec((k, block_b, block_v), lambda bi, vi: (0, bi, vi)),
            pl.BlockSpec((block_b, block_v), lambda bi, vi: (bi, vi)),
        ],
        out_specs=[pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((bp, 1), jnp.float32)] * 3,
        scratch_shapes=[pltpu.VMEM((block_b, 1), jnp.float32) for _ in range(5)],
        interpret=interpret,
    )(w.astype(jnp.float32).reshape(k, 1), client_logits, student_logits)
    if return_stats:
        return out[:b, 0], lse_t[:b, 0], lse_s[:b, 0]
    return out[:b, 0]

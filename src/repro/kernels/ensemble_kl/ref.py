"""Pure-jnp oracle for the fused ensemble-KL kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ensemble_kl_ref(
    client_logits: jax.Array, student_logits: jax.Array, w: jax.Array, temperature: float = 1.0
) -> jax.Array:
    """client_logits: (K, B, V); student_logits: (B, V); w: (K,).
    Returns per-sample KL(softmax(A_w/T) ‖ softmax(s/T))·T², shape (B,)."""
    t = jnp.einsum("k,kbv->bv", w.astype(jnp.float32), client_logits.astype(jnp.float32))
    t = t / temperature
    s = student_logits.astype(jnp.float32) / temperature
    lt = jax.nn.log_softmax(t, axis=-1)
    ls = jax.nn.log_softmax(s, axis=-1)
    return jnp.sum(jnp.exp(lt) * (lt - ls), axis=-1) * (temperature**2)

from repro.kernels.ensemble_kl.ops import ensemble_kl
from repro.kernels.ensemble_kl.ref import ensemble_kl_ref

__all__ = ["ensemble_kl", "ensemble_kl_ref"]

"""Pallas TPU kernels for the framework's compute hot spots.

* :mod:`repro.kernels.ensemble_kl`     — fused weighted-ensemble + KL (Eq. 4)
* :mod:`repro.kernels.ghm_ce`          — fused GHM-difficulty CE (Eq. 5-6)
* :mod:`repro.kernels.flash_attention` — blocked causal/SWA attention
* :mod:`repro.kernels.flash_decode`    — paged Sq=1 decode attention
  (inference-only: claims no backward; the serve engine's paged-KV path)

Each subpackage: ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd differentiable wrapper), ``ref.py`` (pure-jnp oracle).
:mod:`repro.kernels.dispatch` maps the ``backend`` knob ("auto" | "pallas" |
"pallas-interpret" | "ref") to a concrete implementation per JAX backend;
``ensemble_kl``, ``ghm_ce`` and ``flash_attention`` carry ``jax.custom_vjp``
rules on the Pallas paths whose BACKWARDS are fused Pallas kernels too —
the backend choice covers both passes, and "ref" under plain autodiff is the
grad-parity oracle (tests/grad_harness.py).
"""
from repro.kernels.dispatch import (
    BACKEND_OPS,
    BackendPolicy,
    KERNEL_BACKENDS,
    kernel_arm,
    policy_from_flags,
    resolve,
    resolve_backend,
)
from repro.kernels.ensemble_kl import ensemble_kl, ensemble_kl_ref
from repro.kernels.ghm_ce import ghm_ce, ghm_ce_ref
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.flash_decode import flash_decode, flash_decode_ref

__all__ = [
    "flash_decode",
    "flash_decode_ref",
    "BACKEND_OPS",
    "BackendPolicy",
    "KERNEL_BACKENDS",
    "kernel_arm",
    "policy_from_flags",
    "resolve",
    "resolve_backend",
    "ensemble_kl",
    "ensemble_kl_ref",
    "ghm_ce",
    "ghm_ce_ref",
    "flash_attention",
    "flash_attention_ref",
]

"""Pallas TPU kernels for the framework's compute hot spots.

* :mod:`repro.kernels.ensemble_kl`     — fused weighted-ensemble + KL (Eq. 4)
* :mod:`repro.kernels.ghm_ce`          — fused GHM-difficulty CE (Eq. 5-6)
* :mod:`repro.kernels.flash_attention` — blocked causal/SWA attention

Each subpackage: ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper, interpret-mode on CPU), ``ref.py`` (pure-jnp oracle).
"""
from repro.kernels.ensemble_kl import ensemble_kl, ensemble_kl_ref
from repro.kernels.ghm_ce import ghm_ce, ghm_ce_ref
from repro.kernels.flash_attention import flash_attention, flash_attention_ref

__all__ = [
    "ensemble_kl",
    "ensemble_kl_ref",
    "ghm_ce",
    "ghm_ce_ref",
    "flash_attention",
    "flash_attention_ref",
]

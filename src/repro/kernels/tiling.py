"""Shared tile-alignment helpers for the (batch, vocab)-gridded loss kernels.

The VPU tile floor is (8, 128): blocks must never shrink below it, so short
batches / narrow vocabs are zero-padded up to the block instead of the block
being clamped down to the data (the old ``min(block, dim)`` bug produced
sub-(8, 128) tiles whenever B < 8 or V < 128).

Forward and backward kernels share the same ``tile_padding`` result, so a
VJP sees exactly the padded geometry its forward ran on: padded rows enter
the backward with a zero cotangent (all their grads are exactly zero and the
pad is sliced off), and the padded vocab tail is masked in-kernel on both
passes (``p = exp(NEG − lse)`` underflows to exact 0).
"""
from __future__ import annotations

LANE = 128  # minor-dim VPU lane count
SUBLANE = 8  # second-minor (batch) tile floor for f32


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def tile_padding(b: int, v: int, block_b: int, block_v: int) -> tuple[int, int, int, int]:
    """Returns ``(block_b, block_v, pad_b, pad_v)``: both blocks clamped to
    the (8, 128) floor (block_v additionally no wider than the lane-aligned
    vocab), and the zero-padding needed on each data dim. Caller-supplied
    sub-aligned blocks are raised to the floor rather than honored."""
    block_b = round_up(max(block_b, SUBLANE), SUBLANE)
    block_v = min(round_up(max(block_v, LANE), LANE), round_up(v, LANE))
    return block_b, block_v, (-b) % block_b, (-v) % block_v

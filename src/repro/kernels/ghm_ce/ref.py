"""Pure-jnp oracle for the fused GHM-weighted CE kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ghm_ce_ref(
    client_logits: jax.Array,
    labels: jax.Array,
    w: jax.Array,
    weighted: bool = True,
    stop_difficulty_grad: bool = False,
) -> jax.Array:
    """client_logits: (K, B, V); labels: (B,); w: (K,). Per-sample d·CE.
    ``stop_difficulty_grad`` treats d(x) as a constant under autodiff (the
    Eq. 6 generator-loss convention, matching ``ghs_loss``)."""
    t = jnp.einsum("k,kbv->bv", w.astype(jnp.float32), client_logits.astype(jnp.float32))
    lse = jax.scipy.special.logsumexp(t, axis=-1)
    ly = jnp.take_along_axis(t, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    nll = lse - ly
    if not weighted:
        return nll
    d = 1.0 - jnp.exp(ly - lse)
    if stop_difficulty_grad:
        d = jax.lax.stop_gradient(d)
    return d * nll

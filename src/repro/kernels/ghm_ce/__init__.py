from repro.kernels.ghm_ce.ops import ghm_ce
from repro.kernels.ghm_ce.ref import ghm_ce_ref

__all__ = ["ghm_ce", "ghm_ce_ref"]

"""Fused GHM-difficulty-weighted cross-entropy (Eq. 5–6) Pallas TPU kernel.

The hard-sample generator loss weights each sample's CE by its difficulty
d = 1 − softmax(A_w(x))_y. Both quantities come from the same softmax
statistics, so the kernel computes the weighted ensemble tile, the online
logsumexp, and the label logit in one vocab sweep:

    lse  = m + log Σ e^{t−m}        (online across vocab tiles)
    l_y  = t[label]                 (picked up in the tile that owns label)
    out  = (1 − e^{l_y − lse}) · (lse − l_y)

Grid: (batch_tiles, vocab_tiles), vocab minor; scratch: m, d, ly per row.
Labels ride along as a (bb, 1) int32 block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import tile_padding

NEG = -1e30


def _kernel(
    w_ref,
    client_ref,
    label_ref,
    out_ref,
    lse_ref,
    lyo_ref,
    m_ref,
    d_ref,
    ly_ref,
    *,
    num_vocab_tiles: int,
    vocab: int,
    block_v: int,
    weighted: bool,
):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        d_ref[...] = jnp.zeros_like(d_ref)
        ly_ref[...] = jnp.zeros_like(ly_ref)

    w = w_ref[...]  # (K, 1)
    cl = client_ref[...].astype(jnp.float32)  # (K, bb, bv)
    t = jnp.sum(w[:, :, None] * cl, axis=0)  # (bb, bv)

    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, t.shape, 1)
    valid = col < vocab
    t = jnp.where(valid, t, NEG)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(t, axis=-1, keepdims=True))
    d_ref[...] = d_ref[...] * jnp.exp(m_old - m_new) + jnp.sum(
        jnp.exp(t - m_new), axis=-1, keepdims=True
    )
    m_ref[...] = m_new

    labels = label_ref[...]  # (bb, 1) int32
    hit = col == labels  # (bb, bv)
    ly_ref[...] += jnp.sum(jnp.where(hit, t, 0.0), axis=-1, keepdims=True)

    @pl.when(vi == num_vocab_tiles - 1)
    def _final():
        lse = jnp.log(d_ref[...]) + m_ref[...]
        ly = ly_ref[...]
        nll = lse - ly
        if weighted:
            d_hard = 1.0 - jnp.exp(ly - lse)  # Eq. 5
            nll = d_hard * nll  # Eq. 6
        out_ref[...] = nll.astype(out_ref.dtype)
        # the online-softmax statistics double as the VJP residuals
        lse_ref[...] = lse.astype(lse_ref.dtype)
        lyo_ref[...] = ly.astype(lyo_ref.dtype)


def _bwd_kernel(
    w_ref,
    client_ref,
    label_ref,
    g_ref,
    lse_ref,
    ly_ref,
    gcl_ref,
    gw_ref,
    *,
    vocab: int,
    block_v: int,
    weighted: bool,
    stop_difficulty_grad: bool,
):
    """One (batch, vocab) tile of the Eq. 5–6 VJP (see ops.py for the math).

    d(out)/dt factors as coeff · (p − e): ``p`` is rebuilt per tile from the
    saved logsumexp, the one-hot ``e`` from the label block, and the per-row
    ``coeff`` (which mode-switches on ``weighted``/``stop_difficulty_grad``)
    costs only the (bb, 1) residuals. g_cl streams out tile-by-tile; g_w
    accumulates in a VMEM-resident (K, 1) block across the whole grid."""
    bi = pl.program_id(0)
    vi = pl.program_id(1)

    @pl.when((bi == 0) & (vi == 0))
    def _init():
        gw_ref[...] = jnp.zeros_like(gw_ref)

    w = w_ref[...]  # (K, 1) f32
    cl = client_ref[...].astype(jnp.float32)  # (K, bb, bv)
    t = jnp.sum(w[:, :, None] * cl, axis=0)  # (bb, bv)

    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, t.shape, 1)
    valid = col < vocab
    t = jnp.where(valid, t, NEG)

    lse = lse_ref[...]  # (bb, 1)
    ly = ly_ref[...]
    g = g_ref[...]
    p = jnp.exp(t - lse)  # exact 0 on the padded vocab tail
    onehot = (col == label_ref[...]).astype(jnp.float32)  # (bb, bv)

    if not weighted:
        coeff = jnp.ones_like(lse)
    else:
        py = jnp.exp(ly - lse)
        coeff = 1.0 - py
        if not stop_difficulty_grad:
            coeff = coeff + py * (lse - ly)

    g_t = (g * coeff) * (p - onehot)
    g_t = jnp.where(valid, g_t, 0.0)
    gcl_ref[...] = (w[:, :, None] * g_t[None]).astype(gcl_ref.dtype)
    gw_ref[...] += jnp.sum(cl * g_t[None], axis=(1, 2))[:, None]


def ghm_ce_bwd_pallas(
    client_logits: jax.Array,
    labels: jax.Array,
    w: jax.Array,
    g: jax.Array,
    lse: jax.Array,
    ly: jax.Array,
    *,
    weighted: bool = True,
    stop_difficulty_grad: bool = False,
    block_b: int = 8,
    block_v: int = 512,
    interpret: bool = False,
):
    """Fused backward for :func:`ghm_ce_pallas`.

    ``g`` is the per-sample cotangent (B,); ``lse``/``ly`` the forward's
    online residuals (ensemble logsumexp + label logit). Returns
    ``(g_client, g_w)`` with the input dtypes; labels are integer and carry
    no cotangent. Same grid and streaming discipline as the forward."""
    k, b, v = client_logits.shape
    block_b, block_v, pb, pv = tile_padding(b, v, block_b, block_v)
    if pb or pv:
        client_logits = jnp.pad(client_logits, ((0, 0), (0, pb), (0, pv)))
    if pb:
        # padded rows carry label 0 and a ZERO cotangent — every grad is zero
        labels = jnp.pad(labels, ((0, pb),))
        g = jnp.pad(g, ((0, pb),))
        lse = jnp.pad(lse, ((0, pb),))
        ly = jnp.pad(ly, ((0, pb),))
    bp, vp = b + pb, v + pv
    nb, nv = bp // block_b, vp // block_v

    row = lambda x: x.astype(jnp.float32).reshape(bp, 1)
    g_cl, g_w = pl.pallas_call(
        functools.partial(
            _bwd_kernel, vocab=v, block_v=block_v,
            weighted=weighted, stop_difficulty_grad=stop_difficulty_grad,
        ),
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((k, 1), lambda bi, vi: (0, 0)),
            pl.BlockSpec((k, block_b, block_v), lambda bi, vi: (0, bi, vi)),
            pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, block_b, block_v), lambda bi, vi: (0, bi, vi)),
            pl.BlockSpec((k, 1), lambda bi, vi: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, bp, vp), client_logits.dtype),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        w.astype(jnp.float32).reshape(k, 1),
        client_logits,
        labels.astype(jnp.int32).reshape(bp, 1),
        row(g),
        row(lse),
        row(ly),
    )
    return g_cl[:, :b, :v], g_w[:, 0].astype(w.dtype)


def ghm_ce_pallas(
    client_logits: jax.Array,
    labels: jax.Array,
    w: jax.Array,
    *,
    weighted: bool = True,
    block_b: int = 8,
    block_v: int = 512,
    interpret: bool = False,
    return_stats: bool = False,
):
    """client_logits: (K, B, V); labels: (B,) int32; w: (K,).
    Returns per-sample d·CE (or plain CE when ``weighted=False``), (B,);
    with ``return_stats=True`` also the ensemble logsumexp and label logit
    (the VJP residuals), each (B,).

    Tiles never shrink below the (8, 128) VPU alignment: short batches and
    narrow vocabs are zero-padded up to the block instead (padded rows are
    computed on benign zeros and sliced off; the padded vocab tail is masked
    inside the kernel)."""
    k, b, v = client_logits.shape
    block_b, block_v, pb, pv = tile_padding(b, v, block_b, block_v)
    if pb or pv:
        client_logits = jnp.pad(client_logits, ((0, 0), (0, pb), (0, pv)))
    if pb:
        labels = jnp.pad(labels, ((0, pb),))
    bp, vp = b + pb, v + pv
    nb, nv = bp // block_b, vp // block_v

    out, lse, ly = pl.pallas_call(
        functools.partial(
            _kernel, num_vocab_tiles=nv, vocab=v, block_v=block_v, weighted=weighted
        ),
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((k, 1), lambda bi, vi: (0, 0)),
            pl.BlockSpec((k, block_b, block_v), lambda bi, vi: (0, bi, vi)),
            pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0)),
        ],
        out_specs=[pl.BlockSpec((block_b, 1), lambda bi, vi: (bi, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((bp, 1), jnp.float32)] * 3,
        scratch_shapes=[pltpu.VMEM((block_b, 1), jnp.float32) for _ in range(3)],
        interpret=interpret,
    )(
        w.astype(jnp.float32).reshape(k, 1),
        client_logits,
        labels.astype(jnp.int32).reshape(bp, 1),
    )
    if return_stats:
        return out[:b, 0], lse[:b, 0], ly[:b, 0]
    return out[:b, 0]

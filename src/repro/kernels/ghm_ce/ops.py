"""Jitted public wrapper for the fused GHM-weighted CE kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ghm_ce.kernel import ghm_ce_pallas
from repro.kernels.ghm_ce.ref import ghm_ce_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("weighted", "use_kernel", "block_b", "block_v"))
def ghm_ce(
    client_logits: jax.Array,
    labels: jax.Array,
    w: jax.Array,
    weighted: bool = True,
    use_kernel: bool = True,
    block_b: int = 8,
    block_v: int = 512,
) -> jax.Array:
    """Per-sample difficulty-weighted CE of the weighted ensemble (Eq. 6)."""
    if not use_kernel:
        return ghm_ce_ref(client_logits, labels, w, weighted)
    return ghm_ce_pallas(
        client_logits,
        labels,
        w,
        weighted=weighted,
        block_b=block_b,
        block_v=block_v,
        interpret=not _on_tpu(),
    )

"""Differentiable public wrapper for the fused GHM-weighted CE kernel.

``backend`` (see :mod:`repro.kernels.dispatch`) selects the compiled Pallas
TPU kernel, the Pallas interpreter (debug/parity), or the pure-jnp reference
— and the choice covers BOTH passes: the Pallas paths carry a
``jax.custom_vjp`` whose forward returns the kernel's online statistics
(ensemble logsumexp + label logit) as residuals and whose backward is the
fused Pallas kernel :func:`repro.kernels.ghm_ce.kernel.ghm_ce_bwd_pallas`,
streaming cotangents for ``client_logits`` and ``w`` without materializing
A_w (labels are integer — float0 cotangent). ``backend="ref"`` bypasses the
custom_vjp: plain autodiff of the jnp oracle is the parity baseline.

With ``t = A_w``, ``p = softmax(t)``, ``p_y`` the label prob, ``nll`` the CE
and ``e`` the one-hot label, d(out)/dt factors as ``coeff · (p − e)`` where

    coeff = 1                         (weighted=False — plain CE)
          = 1 − p_y                   (weighted, difficulty stop-gradiented
                                       — the Eq. 6 generator-loss convention)
          = 1 − p_y + p_y·nll         (weighted, full gradient)

``stop_difficulty_grad=True`` reproduces :func:`repro.core.hardness.ghs_loss`
treating d(x) as a constant (GHM usage); the default differentiates through
the difficulty weight, matching plain autodiff of :func:`ghm_ce_ref`.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_backend
from repro.kernels.ghm_ce.kernel import ghm_ce_bwd_pallas, ghm_ce_pallas
from repro.kernels.ghm_ce.ref import ghm_ce_ref


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _ghm_ce_kernel(client_logits, labels, w, weighted, stop_difficulty_grad, interpret, block_b, block_v):
    return ghm_ce_pallas(
        client_logits, labels, w, weighted=weighted,
        block_b=block_b, block_v=block_v, interpret=interpret,
    )


def _ghm_ce_fwd(client_logits, labels, w, weighted, stop_difficulty_grad, interpret, block_b, block_v):
    out, lse, ly = ghm_ce_pallas(
        client_logits, labels, w, weighted=weighted,
        block_b=block_b, block_v=block_v, interpret=interpret, return_stats=True,
    )
    return out, (client_logits, labels, w, lse, ly)


def _ghm_ce_bwd(weighted, stop_difficulty_grad, interpret, block_b, block_v, res, g):
    client_logits, labels, w, lse, ly = res
    g_cl, g_w = ghm_ce_bwd_pallas(
        client_logits, labels, w, g, lse, ly,
        weighted=weighted, stop_difficulty_grad=stop_difficulty_grad,
        block_b=block_b, block_v=block_v, interpret=interpret,
    )
    g_labels = np.zeros(labels.shape, dtype=jax.dtypes.float0)
    return g_cl, g_labels, g_w


_ghm_ce_kernel.defvjp(_ghm_ce_fwd, _ghm_ce_bwd)


@partial(jax.jit, static_argnames=("weighted", "backend", "block_b", "block_v", "stop_difficulty_grad"))
def ghm_ce(
    client_logits: jax.Array,
    labels: jax.Array,
    w: jax.Array,
    weighted: bool = True,
    backend: str = "auto",
    block_b: int = 8,
    block_v: int = 512,
    stop_difficulty_grad: bool = False,
) -> jax.Array:
    """Per-sample difficulty-weighted CE of the weighted ensemble (Eq. 6)."""
    resolved = resolve_backend(backend)
    if resolved == "ref":
        return ghm_ce_ref(client_logits, labels, w, weighted, stop_difficulty_grad)
    return _ghm_ce_kernel(
        client_logits, labels, w, weighted, stop_difficulty_grad,
        resolved == "pallas-interpret", block_b, block_v,
    )

"""Jitted public wrapper for the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "use_kernel", "block_q", "block_kv"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    use_kernel: bool = True,
    block_q: int = 256,
    block_kv: int = 256,
) -> jax.Array:
    """Blocked causal/SWA attention. q: (B,Sq,H,hd); k,v: (B,Sk,KH,hd)."""
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_kv=block_kv,
        interpret=not _on_tpu(),
    )

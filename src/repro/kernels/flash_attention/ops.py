"""Jitted public wrapper for the flash-attention kernel.

``backend`` follows :mod:`repro.kernels.dispatch` like the loss kernels:
"auto" is the compiled kernel on TPU and the jnp ref elsewhere — the
interpreter must be requested explicitly ("pallas-interpret"); asking for
"pallas" off-TPU is an error, never a silent interpret fallback.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.dispatch import resolve_backend
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "backend", "block_q", "block_kv"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    backend: str = "auto",
    block_q: int = 256,
    block_kv: int = 256,
) -> jax.Array:
    """Blocked causal/SWA attention. q: (B,Sq,H,hd); k,v: (B,Sk,KH,hd)."""
    resolved = resolve_backend(backend)
    if resolved == "ref":
        return flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_kv=block_kv,
        interpret=resolved == "pallas-interpret",
    )

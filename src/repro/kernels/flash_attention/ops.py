"""Differentiable public wrapper for the flash-attention kernel.

``backend`` follows :mod:`repro.kernels.dispatch` like the loss kernels:
"auto" is the compiled kernel on TPU and the jnp ref elsewhere — the
interpreter must be requested explicitly ("pallas-interpret"); asking for
"pallas" off-TPU is an error, never a silent interpret fallback.

The choice covers BOTH passes: the Pallas paths carry a ``jax.custom_vjp``
whose forward keeps the kernel's per-row logsumexp as the residual and whose
backward is :func:`repro.kernels.flash_attention.kernel.flash_attention_bwd_pallas`
— dq/dk/dv rebuilt tile-by-tile from the saved lse, never re-materializing a
score block in HBM. ``backend="ref"`` differentiates the jnp reference under
plain autodiff — the parity oracle.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.dispatch import resolve_backend
from repro.kernels.flash_attention.kernel import (
    flash_attention_bwd_pallas,
    flash_attention_pallas,
)
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_attn_kernel(q, k, v, causal, window, softcap, interpret, block_q, block_kv):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )


def _flash_attn_fwd(q, k, v, causal, window, softcap, interpret, block_q, block_kv):
    out, lse = flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, interpret=interpret, return_lse=True,
    )
    return out, (q, k, v, out, lse)


def _flash_attn_bwd(causal, window, softcap, interpret, block_q, block_kv, res, dout):
    q, k, v, out, lse = res
    return flash_attention_bwd_pallas(
        q, k, v, out, lse, dout,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )


_flash_attn_kernel.defvjp(_flash_attn_fwd, _flash_attn_bwd)


@partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "backend", "block_q", "block_kv"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    backend: str = "auto",
    block_q: int = 256,
    block_kv: int = 256,
) -> jax.Array:
    """Blocked causal/SWA attention. q: (B,Sq,H,hd); k,v: (B,Sk,KH,hd)."""
    resolved = resolve_backend(backend)
    if resolved == "ref":
        return flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    return _flash_attn_kernel(
        q, k, v, causal, window, softcap,
        resolved == "pallas-interpret", block_q, block_kv,
    )

"""Blocked causal / sliding-window attention Pallas TPU kernel.

The long-context shapes (prefill_32k, long_500k SWA) make attention the
compute hot spot; this kernel is the TPU tiling of the online-softmax
algorithm (same math as :func:`repro.models.attention.flash_attn_jax`, its
lowering-friendly jnp twin):

  * grid (batch·kv_head·q_per_kv, q_tiles, kv_tiles) — kv minor so the
    (m, l, acc) statistics stay in VMEM scratch across a kv sweep;
  * blocks (block_q, head_dim) / (block_kv, head_dim) — head_dim padded to
    the 128-lane width, block_q a multiple of 8 sublanes; the s·v product
    hits the MXU with both contraction dims 128-aligned;
  * causal and sliding-window masks are computed from program ids, and
    fully-masked kv tiles are skipped via the mask check inside @pl.when
    (interpret mode runs them; on TPU the compiler hoists the branch).

GQA is handled by folding q_per_kv into the grid's batch dim so each kernel
instance sees exactly one (q-head, kv-head) pair — no head broadcast inside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    lse_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_kv: int,
    num_kv_tiles: int,
    seq_k: int,
    scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos < seq_k
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ()))
    )
    m_ref[...] = m_new

    @pl.when(ki == num_kv_tiles - 1)
    def _final():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)
        l, m = l_ref[...], m_ref[...]
        # fully-masked rows (padding beyond an SWA tail) get a huge lse so a
        # recompute backward's p = exp(s - lse) underflows to exactly zero
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 1e30)
        lse_ref[...] = lse[:, 0][None, :]  # (block_q, 1) -> (1, block_q)


def _mask_and_p(qs, kb, lse, qi, ki, *, causal, window, softcap, block_q, block_kv, seq_k):
    """Rebuild one (bq, bkv) probability tile from the saved lse.

    Returns (p, dact): the exact forward probabilities (p = exp(s − lse) is
    0 on masked/padded columns because s = NEG there, and 0 on fully-masked
    rows because their saved lse is 1e30) and the softcap chain factor
    dact = 1 − tanh²(u/cap) evaluated at the pre-cap scores (1 without
    softcap)."""
    s = jax.lax.dot_general(qs, kb, (((1,), (1,)), ((), ())))  # (bq, bkv)
    if softcap > 0:
        t = jnp.tanh(s / softcap)
        dact = 1.0 - t * t
        s = t * softcap
    else:
        dact = 1.0
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos < seq_k
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG)
    p = jnp.exp(s - lse)
    return p, dact


def _bwd_dq_kernel(
    q_ref,
    k_ref,
    v_ref,
    do_ref,
    lse_ref,
    delta_ref,
    dq_ref,
    acc_ref,
    *,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_kv: int,
    num_kv_tiles: int,
    seq_k: int,
    scale: float,
):
    """dq pass: kv minor, so the (bq, hd) dq accumulator stays in VMEM
    scratch across a kv sweep — the score tile is recomputed from the saved
    lse, never re-materialized in HBM."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qs = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
    kb = k_ref[0].astype(jnp.float32)  # (bkv, hd)
    vb = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)  # (bq, hd)
    lse = lse_ref[0][:, None]  # (bq, 1)
    delta = delta_ref[0][:, None]

    p, dact = _mask_and_p(
        qs, kb, lse, qi, ki, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, seq_k=seq_k,
    )
    dp = jax.lax.dot_general(do, vb, (((1,), (1,)), ((), ())))  # (bq, bkv)
    du = p * (dp - delta) * dact  # grad wrt the pre-cap scores u = qs·kᵀ
    acc_ref[...] += jax.lax.dot_general(du, kb, (((1,), (0,)), ((), ())))

    @pl.when(ki == num_kv_tiles - 1)
    def _final():
        dq_ref[0] = (acc_ref[...] * scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(
    q_ref,
    k_ref,
    v_ref,
    do_ref,
    lse_ref,
    delta_ref,
    dk_ref,
    dv_ref,
    dk_acc,
    dv_acc,
    *,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_kv: int,
    num_q_tiles: int,
    seq_k: int,
    scale: float,
):
    """dk/dv pass: q minor, so the two (bkv, hd) accumulators stay in VMEM
    scratch across a q sweep. Emits per-q-head dk/dv (the wrapper reduces
    the GQA broadcast over g outside)."""
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    qs = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
    kb = k_ref[0].astype(jnp.float32)  # (bkv, hd)
    vb = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, None]
    delta = delta_ref[0][:, None]

    p, dact = _mask_and_p(
        qs, kb, lse, qi, ki, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, seq_k=seq_k,
    )
    dp = jax.lax.dot_general(do, vb, (((1,), (1,)), ((), ())))
    du = p * (dp - delta) * dact
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))  # pᵀ·do
    dk_acc[...] += jax.lax.dot_general(du, qs, (((0,), (0,)), ((), ())))  # duᵀ·qs

    @pl.when(qi == num_q_tiles - 1)
    def _final():
        dk_ref[0] = dk_acc[...]
        dv_ref[0] = dv_acc[...]


def flash_attention_bwd_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    out: jax.Array,
    lse: jax.Array,
    dout: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 256,
    block_kv: int = 256,
    interpret: bool = False,
):
    """Fused backward for :func:`flash_attention_pallas`.

    ``out``/``lse`` are the forward's output and per-row logsumexp
    (``return_lse=True``); ``dout`` the output cotangent. Returns
    ``(dq, dk, dv)`` with the input dtypes. Two streamed passes over the
    forward's tiling — dq with kv minor, dk/dv with q minor — each
    rebuilding the probability tile from the saved lse instead of
    re-materializing score blocks; delta = Σ dout·out is the only jnp
    precompute (O(S·hd)). dk/dv come out per q-head and are reduced over
    the GQA group outside the kernel."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / (hd**0.5)
    block_q = min(block_q, max(8, sq))
    block_kv = min(block_kv, max(8, sk))
    pq = (-sq) % block_q
    pk = (-sk) % block_kv

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # (B,Sq,H)
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        dout = jnp.pad(dout, ((0, 0), (0, pq), (0, 0), (0, 0)))
        # padded q rows: lse=1e30 makes p underflow to exact 0, delta=0
        lse = jnp.pad(lse, ((0, 0), (0, pq), (0, 0)), constant_values=1e30)
        delta = jnp.pad(delta, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sqp, skp = sq + pq, sk + pk
    nq, nk = sqp // block_q, skp // block_kv

    bhg = b * kh * g
    qf = q.reshape(b, sqp, kh, g, hd).transpose(0, 2, 3, 1, 4).reshape(bhg, sqp, hd)
    dof = dout.reshape(b, sqp, kh, g, hd).transpose(0, 2, 3, 1, 4).reshape(bhg, sqp, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, skp, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, skp, hd)
    lsef = lse.reshape(b, sqp, kh, g).transpose(0, 2, 3, 1).reshape(bhg, sqp)
    deltaf = delta.reshape(b, sqp, kh, g).transpose(0, 2, 3, 1).reshape(bhg, sqp)

    common = dict(
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, seq_k=sk, scale=scale,
    )
    in_specs_q_minorless = [  # shared operand layout for both passes
        pl.BlockSpec((1, block_q, hd), lambda bh, i, j, g=g: (bh, i, 0)),
        pl.BlockSpec((1, block_kv, hd), lambda bh, i, j, g=g: (bh // g, j, 0)),
        pl.BlockSpec((1, block_kv, hd), lambda bh, i, j, g=g: (bh // g, j, 0)),
        pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
        pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i)),
        pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i)),
    ]

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, num_kv_tiles=nk, **common),
        grid=(bhg, nq, nk),
        in_specs=in_specs_q_minorless,
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhg, sqp, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    # q-minor pass: same operands, grid dims (bh, ki, qi) — swap the maps
    in_specs_kv = [
        pl.BlockSpec((1, block_q, hd), lambda bh, ki, qi: (bh, qi, 0)),
        pl.BlockSpec((1, block_kv, hd), lambda bh, ki, qi, g=g: (bh // g, ki, 0)),
        pl.BlockSpec((1, block_kv, hd), lambda bh, ki, qi, g=g: (bh // g, ki, 0)),
        pl.BlockSpec((1, block_q, hd), lambda bh, ki, qi: (bh, qi, 0)),
        pl.BlockSpec((1, block_q), lambda bh, ki, qi: (bh, qi)),
        pl.BlockSpec((1, block_q), lambda bh, ki, qi: (bh, qi)),
    ]
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, num_q_tiles=nq, **common),
        grid=(bhg, nk, nq),
        in_specs=in_specs_kv,
        out_specs=[pl.BlockSpec((1, block_kv, hd), lambda bh, ki, qi: (bh, ki, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((bhg, skp, hd), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((block_kv, hd), jnp.float32)] * 2,
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    dq = dq.reshape(b, kh, g, sqp, hd).transpose(0, 3, 1, 2, 4).reshape(b, sqp, h, hd)
    # reduce the GQA group onto the kv heads, then restore (B, Sk, KH, hd)
    dk = dk_h.reshape(b, kh, g, skp, hd).sum(2).transpose(0, 2, 1, 3)
    dv = dv_h.reshape(b, kh, g, skp, hd).sum(2).transpose(0, 2, 1, 3)
    return (
        dq[:, :sq],
        dk[:, :sk].astype(k.dtype),
        dv[:, :sk].astype(v.dtype),
    )


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 256,
    block_kv: int = 256,
    interpret: bool = False,
    return_lse: bool = False,
):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KH, hd), H % KH == 0.
    Returns (B, Sq, H, hd), plus the per-row logsumexp (B, Sq, H) when
    ``return_lse`` (the residual a recompute backward needs)."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / (hd**0.5)
    block_q = min(block_q, max(8, sq))
    block_kv = min(block_kv, max(8, sk))
    pq = (-sq) % block_q
    pk = (-sk) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sqp, skp = sq + pq, sk + pk
    nq, nk = sqp // block_q, skp // block_kv

    # fold (B, KH, G) into one grid dim; layout (BHG, S, hd)
    qf = q.reshape(b, sqp, kh, g, hd).transpose(0, 2, 3, 1, 4).reshape(b * kh * g, sqp, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, skp, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, skp, hd)

    out, lse = pl.pallas_call(
        functools.partial(
            _kernel,
            causal=causal,
            window=window,
            softcap=softcap,
            block_q=block_q,
            block_kv=block_kv,
            num_kv_tiles=nk,
            seq_k=sk,
            scale=scale,
        ),
        grid=(b * kh * g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda bh, qi, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda bh, qi, ki: (bh // g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * kh * g, sqp, hd), q.dtype),
            jax.ShapeDtypeStruct((b * kh * g, sqp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, kh, g, sqp, hd).transpose(0, 3, 1, 2, 4).reshape(b, sqp, h, hd)
    if not return_lse:
        return out[:, :sq]
    lse = lse.reshape(b, kh, g, sqp).transpose(0, 3, 1, 2).reshape(b, sqp, h)
    return out[:, :sq], lse[:, :sq]

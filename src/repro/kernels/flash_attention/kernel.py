"""Blocked causal / sliding-window attention Pallas TPU kernel.

The long-context shapes (prefill_32k, long_500k SWA) make attention the
compute hot spot; this kernel is the TPU tiling of the online-softmax
algorithm (same math as :func:`repro.models.attention.flash_attn_jax`, its
lowering-friendly jnp twin):

  * grid (batch·kv_head·q_per_kv, q_tiles, kv_tiles) — kv minor so the
    (m, l, acc) statistics stay in VMEM scratch across a kv sweep;
  * blocks (block_q, head_dim) / (block_kv, head_dim) — head_dim padded to
    the 128-lane width, block_q a multiple of 8 sublanes; the s·v product
    hits the MXU with both contraction dims 128-aligned;
  * causal and sliding-window masks are computed from program ids, and
    fully-masked kv tiles are skipped via the mask check inside @pl.when
    (interpret mode runs them; on TPU the compiler hoists the branch).

GQA is handled by folding q_per_kv into the grid's batch dim so each kernel
instance sees exactly one (q-head, kv-head) pair — no head broadcast inside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    lse_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_kv: int,
    num_kv_tiles: int,
    seq_k: int,
    scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos < seq_k
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ()))
    )
    m_ref[...] = m_new

    @pl.when(ki == num_kv_tiles - 1)
    def _final():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)
        l, m = l_ref[...], m_ref[...]
        # fully-masked rows (padding beyond an SWA tail) get a huge lse so a
        # recompute backward's p = exp(s - lse) underflows to exactly zero
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 1e30)
        lse_ref[...] = lse[:, 0][None, :]  # (block_q, 1) -> (1, block_q)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 256,
    block_kv: int = 256,
    interpret: bool = False,
    return_lse: bool = False,
):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KH, hd), H % KH == 0.
    Returns (B, Sq, H, hd), plus the per-row logsumexp (B, Sq, H) when
    ``return_lse`` (the residual a recompute backward needs)."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / (hd**0.5)
    block_q = min(block_q, max(8, sq))
    block_kv = min(block_kv, max(8, sk))
    pq = (-sq) % block_q
    pk = (-sk) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sqp, skp = sq + pq, sk + pk
    nq, nk = sqp // block_q, skp // block_kv

    # fold (B, KH, G) into one grid dim; layout (BHG, S, hd)
    qf = q.reshape(b, sqp, kh, g, hd).transpose(0, 2, 3, 1, 4).reshape(b * kh * g, sqp, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, skp, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, skp, hd)

    out, lse = pl.pallas_call(
        functools.partial(
            _kernel,
            causal=causal,
            window=window,
            softcap=softcap,
            block_q=block_q,
            block_kv=block_kv,
            num_kv_tiles=nk,
            seq_k=sk,
            scale=scale,
        ),
        grid=(b * kh * g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda bh, qi, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda bh, qi, ki: (bh // g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * kh * g, sqp, hd), q.dtype),
            jax.ShapeDtypeStruct((b * kh * g, sqp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, kh, g, sqp, hd).transpose(0, 3, 1, 2, 4).reshape(b, sqp, h, hd)
    if not return_lse:
        return out[:, :sq]
    lse = lse.reshape(b, kh, g, sqp).transpose(0, 3, 1, 2).reshape(b, sqp, h)
    return out[:, :sq], lse[:, :sq]

"""Pure-jnp oracle for the flash-attention kernel (naive materialized
softmax — O(Sq·Sk) memory, tests only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KH, hd). Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, hd).astype(jnp.float32) / (hd**0.5)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)

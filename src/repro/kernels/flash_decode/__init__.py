"""Paged flash-decode (Sq=1) attention: Pallas TPU kernel + blocked-jnp ref.

The decode-side counterpart of :mod:`repro.kernels.flash_attention` — one
query token per slot against the :class:`repro.serve.kv_pool.KVPool` paged KV
cache, gathered through a per-slot page table with online-softmax
accumulation over pages. Same feature matrix as the prefill kernel (GQA,
sliding-window ring, logit softcap); inference-only by contract (no backward
is claimed — differentiating raises).
"""
from repro.kernels.flash_decode.kernel import flash_decode_pallas
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import flash_decode_ref, page_mask

__all__ = ["flash_decode", "flash_decode_pallas", "flash_decode_ref", "page_mask"]

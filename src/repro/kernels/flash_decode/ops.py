"""Dispatching wrapper for paged flash-decode attention.

``backend`` follows :mod:`repro.kernels.dispatch` semantics ("auto" is the
compiled Pallas kernel on TPU and the blocked-jnp ref twin elsewhere; "auto"
never interprets off-TPU). This is the op :func:`repro.models.attention.
attn_decode` calls for ``kv_layout="paged"`` engine states, routed by
``ModelConfig.decode_backend``.

**Inference-only**: unlike ``flash_attention``/``ensemble_kl``/``ghm_ce``,
this op claims NO custom_vjp backward — decode serves frozen weights and must
never silently enter a loss path (where its missing backward would otherwise
fall back to differentiating a gather-heavy graph, or the Pallas kernel would
fail deep inside a trace). Differentiating it raises immediately with a clear
message; tests pin this.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_backend
from repro.kernels.flash_decode.kernel import flash_decode_pallas
from repro.kernels.flash_decode.ref import flash_decode_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash_decode(q, k_pages, v_pages, page_table, pos, window, softcap, cache_len, impl):
    if impl == "ref":
        return flash_decode_ref(
            q, k_pages, v_pages, page_table, pos,
            window=window, softcap=softcap, cache_len=cache_len,
        )
    return flash_decode_pallas(
        q, k_pages, v_pages, page_table, pos,
        window=window, softcap=softcap, cache_len=cache_len,
        interpret=impl == "pallas-interpret",
    )


def _fwd(q, k_pages, v_pages, page_table, pos, window, softcap, cache_len, impl):
    out = _flash_decode(q, k_pages, v_pages, page_table, pos, window, softcap, cache_len, impl)
    return out, None


def _bwd(window, softcap, cache_len, impl, res, dout):
    raise NotImplementedError(
        "flash_decode is inference-only: it claims no custom_vjp backward "
        "(decode serves frozen weights). Gradients must flow through the "
        "train/prefill path (flash_attention / flash_attn_jax), never the "
        "paged decode cache."
    )


_flash_decode.defvjp(_fwd, _bwd)


def flash_decode(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
    softcap: float = 0.0,
    cache_len: int = 0,
    backend: str | None = "auto",
) -> jax.Array:
    """Paged Sq=1 attention. q: (B, H, hd); k_pages/v_pages: (P, ps, KH, hd);
    page_table: (B, W) int32; pos: (B,) int32 per-row positions.
    ``cache_len`` is the slot's true logical cache length (the SWA ring
    length); 0 means the full table extent W·ps. Returns (B, H, hd)."""
    impl = resolve_backend(backend)
    return _flash_decode(
        q, k_pages, v_pages,
        page_table.astype(jnp.int32), jnp.asarray(pos, jnp.int32).reshape(-1),
        int(window), float(softcap), int(cache_len), impl,
    )

"""Paged flash-decode (Sq = 1) attention Pallas TPU kernel.

Decode attention against the :class:`repro.serve.kv_pool.KVPool` paged cache:
each grid step gathers ONE fixed-size KV page through the per-slot page table
and folds it into VMEM-resident online-softmax statistics, so HBM traffic is
the live pages only — never a dense ``(slots, max_len)`` rectangle.

  * grid ``(B, KH, W)`` — pages minor, so the (m, l, acc) scratch carries one
    row's statistics across its page sweep;
  * the page table and per-row positions ride in as **scalar prefetch**
    (:class:`pltpu.PrefetchScalarGridSpec`): the K/V BlockSpec index maps read
    ``table[b, w]`` to DMA the right page — the gather happens in the
    pipeline, not the kernel body;
  * masking reconstructs each logical index's absolute position from the
    row's position scalar (sliding-window ring math identical to the dense
    ``attn_decode``), and fully-masked pages are skipped via ``@pl.when``;
  * GQA puts the ``q_per_kv`` query heads of one (row, kv-head) pair on the
    MXU tile's sublanes — tiny tiles (g ≤ 8 rows), which is the nature of
    Sq=1 decode; batching across slots is the engine's job, not the grid's.

Unallocated page-table entries point at the pool's scratch page — a valid
page id whose reads are fully masked (it exists as a safe DMA/write target;
see :class:`repro.serve.kv_pool.KVPool`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(
    table_ref,  # scalar prefetch: (B, W) int32 page table
    pos_ref,  # scalar prefetch: (B,) int32 per-row positions
    q_ref,  # (1, 1, G, hd)
    k_ref,  # (1, ps, 1, hd) — the page picked by the index map
    v_ref,
    o_ref,  # (1, 1, G, hd)
    m_ref,  # VMEM (G, 1)
    l_ref,  # VMEM (G, 1)
    acc_ref,  # VMEM (G, hd)
    *,
    window: int,
    softcap: float,
    page_size: int,
    num_pages: int,
    cache_len: int,
    scale: float,
):
    b = pl.program_id(0)
    wi = pl.program_id(2)

    @pl.when(wi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p_b = pos_ref[b]
    # a page is live iff some logical index in [wi·ps, wi·ps + ps) is valid:
    # windowless caches fill front-to-back (live iff base <= p); ring caches
    # are live everywhere once wrapped, and front-to-back before that.
    base = wi * page_size
    page_live = (base <= p_b) & (base < cache_len)
    if window > 0:
        page_live |= (p_b >= cache_len) & (base < cache_len)

    @pl.when(page_live)
    def _page():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (ps, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, ps)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        j = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if window > 0:
            slot_w = p_b % cache_len
            wrap = (p_b // cache_len) * cache_len
            k_pos = jnp.where(j <= slot_w, wrap + j, wrap - cache_len + j)
            ok = (k_pos >= 0) & (k_pos <= p_b) & (k_pos > p_b - window)
        else:
            ok = j <= p_b
        ok &= j < cache_len
        s = jnp.where(ok, s, NEG)

        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
        p_exp = jnp.exp(s - m_new)
        corr = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p_exp, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p_exp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(wi == num_pages - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_pallas(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
    softcap: float = 0.0,
    cache_len: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, hd); k_pages/v_pages: (P, ps, KH, hd) with H % KH == 0;
    page_table: (B, W) int32; pos: (B,) int32. Returns (B, H, hd)."""
    b, h, hd = q.shape
    ps, kh = k_pages.shape[1], k_pages.shape[2]
    w = page_table.shape[1]
    g = h // kh
    cl = cache_len or w * ps
    qf = q.reshape(b, kh, g, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, w),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, ki, wi, tbl, psc: (bi, ki, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), lambda bi, ki, wi, tbl, psc: (tbl[bi, wi], 0, ki, 0)),
            pl.BlockSpec((1, ps, 1, hd), lambda bi, ki, wi, tbl, psc: (tbl[bi, wi], 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, ki, wi, tbl, psc: (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            window=window,
            softcap=softcap,
            page_size=ps,
            num_pages=w,
            cache_len=cl,
            scale=1.0 / float(hd) ** 0.5,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.reshape(-1).astype(jnp.int32), qf, k_pages, v_pages)
    return out.reshape(b, h, hd)

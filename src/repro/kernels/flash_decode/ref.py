"""Blocked-jnp reference twin of the paged flash-decode kernel.

Same math as :func:`repro.kernels.flash_decode.kernel.flash_decode_pallas` —
a ``lax.scan`` over KV pages with online-softmax accumulation — written in
pure jnp so it runs (and is the parity baseline) everywhere the Pallas
interpreter is too slow or unavailable. This is what ``decode_backend="auto"``
resolves to off-TPU, so the CPU CI serve lanes exercise exactly this path.

The logical cache of a slot is the concatenation of its pages in page-table
order: logical index ``j`` lives at ``(page_table[b, j // ps], j % ps)``.
For sliding-window layers the logical space is the dense path's ring of
``cache_len`` slots, so the masking math below mirrors
:func:`repro.models.attention.attn_decode` exactly — that is what makes
paged==dense token parity hold.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def page_mask(j: jax.Array, p: jax.Array, cache_len: int, window: int) -> jax.Array:
    """Validity of logical in-ring index ``j`` for a row at position ``p``.

    Mirrors the dense ``attn_decode`` bias: without a window, ``j`` IS the
    absolute position; with one, the ring of ``cache_len`` slots holds the
    last ``cache_len`` positions and ``j``'s absolute position is
    reconstructed from the write head ``p % cache_len``. ``j >= cache_len``
    (page-size padding past the ring) is always invalid."""
    if window > 0:
        slot_w = p % cache_len
        wrap = (p // cache_len) * cache_len
        k_pos = jnp.where(j <= slot_w, wrap + j, wrap - cache_len + j)
        valid = (k_pos >= 0) & (k_pos <= p) & (k_pos > p - window)
    else:
        valid = j <= p
    return valid & (j < cache_len)


def flash_decode_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
    softcap: float = 0.0,
    cache_len: int = 0,
) -> jax.Array:
    """Sq=1 paged attention. q: (B, H, hd); k_pages/v_pages: (P, ps, KH, hd);
    page_table: (B, W) int32; pos: (B,) int32. Returns (B, H, hd)."""
    b, h, hd = q.shape
    ps, kh = k_pages.shape[1], k_pages.shape[2]
    w = page_table.shape[1]
    cl = cache_len or w * ps
    g = h // kh
    scale = 1.0 / float(hd) ** 0.5
    qf = q.reshape(b, kh, g, hd).astype(jnp.float32) * scale
    posv = pos.reshape(-1).astype(jnp.int32)

    def page_step(carry, wi):
        m, l, acc = carry
        pids = page_table[:, wi]  # (B,) — one page per row per step
        k = k_pages[pids].astype(jnp.float32)  # (B, ps, KH, hd)
        v = v_pages[pids].astype(jnp.float32)
        s = jnp.einsum("bkgd,bskd->bkgs", qf, k)  # (B, KH, G, ps)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        j = wi * ps + jnp.arange(ps, dtype=jnp.int32)  # (ps,) logical indices
        valid = page_mask(j[None, :], posv[:, None], cl, window)  # (B, ps)
        s = jnp.where(valid[:, None, None, :], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p_exp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p_exp, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgs,bskd->bkgd", p_exp, v)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kh, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kh, g), jnp.float32)
    a0 = jnp.zeros((b, kh, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(page_step, (m0, l0, a0), jnp.arange(w, dtype=jnp.int32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, hd).astype(q.dtype)

"""Kernel backend dispatch: which implementation of a Pallas-backed op
actually runs on this process' default JAX backend.

Values (the ``kernel_backend`` knob on :class:`repro.config.train.OFLConfig`,
the ``attn_backend``/``decode_backend`` knobs on ``ModelConfig``, and the
``backend=`` kwarg of :func:`repro.kernels.ensemble_kl` /
:func:`repro.kernels.ghm_ce` / :func:`repro.kernels.flash_decode`):

* ``"auto"``             — ``"pallas"`` on TPU, ``"ref"`` everywhere else.
                           CPU/GPU production paths must never silently run
                           the Pallas interpreter (orders of magnitude slower
                           than XLA on the same math), so auto never picks it.
* ``"pallas"``           — the compiled Pallas TPU kernel. Asking for it off
                           TPU is an error, not a silent interpret fallback.
* ``"pallas-interpret"`` — the Pallas kernel body under the interpreter.
                           Debug/parity lane: runs anywhere, bit-for-bit the
                           kernel's math, slow. This is what the CPU test
                           suite and the kernelpath A/B use.
* ``"ref"``              — the pure-jnp oracle (XLA-fused). Differentiable by
                           plain autodiff; the custom_vjp path is bypassed.

``resolve_backend`` is evaluated at trace/make time (the choice is static in
the jitted programs), so a resolved value never changes mid-run.
"""
from __future__ import annotations

import jax

KERNEL_BACKENDS = ("auto", "pallas", "pallas-interpret", "ref")


def resolve_backend(backend: str | None = "auto") -> str:
    """Map a requested backend to a concrete one ("pallas" | "pallas-interpret"
    | "ref"), validating it against the running JAX backend."""
    if backend is None:
        backend = "auto"
    if backend not in KERNEL_BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {KERNEL_BACKENDS}"
        )
    on_tpu = jax.default_backend() == "tpu"
    if backend == "auto":
        return "pallas" if on_tpu else "ref"
    if backend == "pallas" and not on_tpu:
        raise ValueError(
            "kernel_backend='pallas' requires a TPU backend "
            f"(running on {jax.default_backend()!r}); use 'pallas-interpret' "
            "for debugging or 'ref' for the XLA-fused jnp path"
        )
    return backend


def kernel_arm() -> str:
    """The kernel arm of an explicit kernel-vs-ref A/B: the compiled Pallas
    kernel on TPU, the interpreter elsewhere. Benchmarks/tests must request
    this explicitly — "auto" resolves to "ref" off-TPU, which would time the
    reference against itself."""
    return "pallas" if jax.default_backend() == "tpu" else "pallas-interpret"

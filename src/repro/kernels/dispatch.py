"""Kernel backend dispatch: which implementation of a Pallas-backed op
actually runs on this process' default JAX backend.

A backend choice covers BOTH passes of a differentiable op: the "pallas" /
"pallas-interpret" paths run the fused Pallas forward AND the fused Pallas
backward (their ``custom_vjp`` backward follows the forward's interpret
flag), while "ref" differentiates the pure-jnp oracle under plain autodiff
— the parity baseline the grad harness (tests/grad_harness.py) checks the
kernel VJPs against. ``flash_decode`` is the exception: inference-only, its
backward raises.

The unified entry point is :func:`resolve`, keyed by *op*:

* ``"loss"``   — the Eq. 4/6/11-12 fused losses (``ensemble_kl`` / ``ghm_ce``)
* ``"attn"``   — train/prefill flash attention (``flash_attention``)
* ``"decode"`` — paged Sq=1 decode attention (``flash_decode``)

and by *backend* value:

* ``"auto"``             — ``"pallas"`` on TPU, ``"ref"`` everywhere else.
                           CPU/GPU production paths must never silently run
                           the Pallas interpreter (orders of magnitude slower
                           than XLA on the same math), so auto never picks it.
* ``"pallas"``           — the compiled Pallas TPU kernel. Asking for it off
                           TPU is an error, not a silent interpret fallback.
* ``"pallas-interpret"`` — the Pallas kernel body under the interpreter.
                           Debug/parity lane: runs anywhere, bit-for-bit the
                           kernel's math, slow. This is what the CPU test
                           suite and the kernelpath A/B use.
* ``"ref"``              — the pure-jnp oracle (XLA-fused). Differentiable by
                           plain autodiff; the custom_vjp path is bypassed.

:class:`BackendPolicy` bundles one choice per op (plus a shared default) and
is the single configuration surface for all of them: ``OFLConfig.backend``
and ``ModelConfig.backend`` carry one, and every ``--*-backend`` CLI flag
routes through :func:`policy_from_flags`. The scattered per-op knobs the
policy replaced — ``OFLConfig.kernel_backend``, ``ModelConfig.attn_backend``,
``ModelConfig.decode_backend`` — survive as deprecated aliases that forward
into the policy (``cfg.backend_for(op)`` on either config resolves the
precedence: an explicit policy wins, else the alias).

Resolution happens at trace/make time (the choice is static in the jitted
programs), so a resolved value never changes mid-run.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Optional

import jax

KERNEL_BACKENDS = ("auto", "pallas", "pallas-interpret", "ref")

#: The ops the dispatch layer routes; each has one slot on BackendPolicy.
BACKEND_OPS = ("loss", "attn", "decode")


def _check_value(value: str, what: str) -> None:
    if value not in KERNEL_BACKENDS:
        raise ValueError(
            f"unknown {what} {value!r}; expected one of {KERNEL_BACKENDS}"
        )


@dataclass(frozen=True)
class BackendPolicy:
    """One backend choice per dispatched op, with a shared default.

    Empty per-op fields fall back to ``default``; every field takes the
    :data:`KERNEL_BACKENDS` values. Construct directly, or from CLI flags via
    :func:`policy_from_flags`.
    """

    default: str = "auto"
    loss: str = ""  # ensemble_kl / ghm_ce (the OFL fused-epoch losses)
    attn: str = ""  # train/prefill flash attention
    decode: str = ""  # paged Sq=1 decode attention

    def __post_init__(self):
        _check_value(self.default, "backend")
        for op in BACKEND_OPS:
            v = getattr(self, op)
            if v:
                _check_value(v, f"{op} backend")

    def for_op(self, op: str) -> str:
        """The requested (unresolved) backend for ``op``."""
        if op not in BACKEND_OPS:
            raise ValueError(f"unknown backend op {op!r}; expected one of {BACKEND_OPS}")
        return getattr(self, op) or self.default

    def resolve(self, op: str, platform: Optional[str] = None) -> str:
        return resolve(op, self.for_op(op), platform=platform)

    def replace(self, **kw) -> "BackendPolicy":
        return dataclasses.replace(self, **kw)


def resolve(op: str, backend: Optional[str] = "auto", platform: Optional[str] = None) -> str:
    """Map (op, requested backend) to a concrete implementation choice
    ("pallas" | "pallas-interpret" | "ref") on ``platform`` (default: the
    running JAX backend). This is the single entry point every dispatched op
    goes through; ``op`` scopes validation/error messages and is the
    extension point for per-op auto rules."""
    if op not in BACKEND_OPS:
        raise ValueError(f"unknown backend op {op!r}; expected one of {BACKEND_OPS}")
    if backend is None:
        backend = "auto"
    _check_value(backend, f"{op} backend")
    on_tpu = (platform or jax.default_backend()) == "tpu"
    if backend == "auto":
        return "pallas" if on_tpu else "ref"
    if backend == "pallas" and not on_tpu:
        raise ValueError(
            f"{op} backend 'pallas' requires a TPU backend "
            f"(running on {platform or jax.default_backend()!r}); use "
            "'pallas-interpret' for debugging or 'ref' for the XLA-fused jnp path"
        )
    return backend


def resolve_backend(backend: Optional[str] = "auto") -> str:
    """Back-compat shim for the original single-knob entry (op-agnostic:
    resolution rules are currently identical across ops). Prefer
    :func:`resolve` / :meth:`BackendPolicy.resolve`."""
    if backend is not None and backend not in KERNEL_BACKENDS:
        # the pre-policy error wording, which callers and tests match on
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {KERNEL_BACKENDS}"
        )
    return resolve("loss", backend)


def policy_from_flags(
    backend: Optional[str] = None,
    kernel_backend: Optional[str] = None,
    attn_backend: Optional[str] = None,
    decode_backend: Optional[str] = None,
    warn: bool = True,
) -> BackendPolicy:
    """Build a :class:`BackendPolicy` from CLI flag values. ``backend`` is
    the new unified ``--backend`` flag (the policy default); the per-op
    arguments are the deprecated ``--kernel-backend`` / ``--attn-backend`` /
    ``--decode-backend`` flags, which still work but warn. ``None`` means
    "flag not given"."""
    fields = {}
    for op, value, flag in (
        ("loss", kernel_backend, "--kernel-backend"),
        ("attn", attn_backend, "--attn-backend"),
        ("decode", decode_backend, "--decode-backend"),
    ):
        if value is not None:
            if warn:
                warnings.warn(
                    f"{flag} is deprecated; use --backend (all ops) or a "
                    f"BackendPolicy({op}=...) — forwarding to the policy",
                    DeprecationWarning,
                    stacklevel=2,
                )
            fields[op] = value
    return BackendPolicy(default=backend or "auto", **fields)


def kernel_arm() -> str:
    """The kernel arm of an explicit kernel-vs-ref A/B: the compiled Pallas
    kernel on TPU, the interpreter elsewhere. Benchmarks/tests must request
    this explicitly — "auto" resolves to "ref" off-TPU, which would time the
    reference against itself."""
    return "pallas" if jax.default_backend() == "tpu" else "pallas-interpret"

from repro.runtime.steps import (
    make_train_step,
    make_distill_step_lm,
    make_prefill_step,
    make_decode_step,
)

__all__ = [
    "make_train_step",
    "make_distill_step_lm",
    "make_prefill_step",
    "make_decode_step",
]

"""Jit-able runtime steps for the LM substrate.

Every step is a *pure function factory*: ``make_*_step(cfg, ...)`` returns a
function suitable for ``jax.jit`` / ``.lower().compile()`` under a mesh —
these are exactly the programs the multi-pod dry-run lowers (launch/dryrun).

* ``train_step``   — CE language-model training (the client-pretraining
                     substrate and the e2e example driver), with optional
                     gradient micro-batching.
* ``distill_step`` — Co-Boosting server distillation at LM scale (Eq. 4 over
                     the stacked client ensemble; the paper's technique).
* ``prefill_step`` / ``decode_step`` — serving (inference shapes).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.train import TrainConfig
from repro.core.distributed import coboost_distill_loss
from repro.models.transformer import lm_decode, lm_forward, lm_loss, lm_prefill
from repro.optim import make_optimizer
from repro.optim.optimizers import apply_updates, clip_by_global_norm
from repro.utils import tree_zeros_like


def make_train_step(cfg, tc: TrainConfig) -> Callable:
    """Returns step(params, opt_state, batch, step_idx) ->
    (params, opt_state, metrics)."""
    opt = make_optimizer(tc)

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(params, opt_state, batch, step_idx):
        if tc.microbatches > 1:
            def split(x):
                return x.reshape(tc.microbatches, x.shape[0] // tc.microbatches, *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def body(carry, mb):
                loss_acc, grads_acc = carry
                loss, metrics, grads = grads_of(params, mb)
                grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), tree_zeros_like(params)), micro
            )
            loss = loss / tc.microbatches
            grads = jax.tree_util.tree_map(lambda g: g / tc.microbatches, grads)
            metrics = {"ce": loss}
        else:
            loss, metrics, grads = grads_of(params, batch)
        if tc.grad_dtype:
            grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.dtype(tc.grad_dtype)), grads)
        if tc.grad_clip_norm > 0:
            grads = clip_by_global_norm(grads, tc.grad_clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params, step_idx)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    step.optimizer = opt
    return step


def make_distill_step_lm(cfg, tc: TrainConfig, temperature: float = 4.0, kl_chunk: int = 0) -> Callable:
    """Returns step(server_params, opt_state, stacked_client_params, w,
    batch, step_idx) — the LM-scale Co-Boosting distillation step (the
    paper-technique program the dry-run exercises). ``kl_chunk`` enables
    the chunked-logits memory lever (§Perf)."""
    opt = make_optimizer(tc)

    def step(server_params, opt_state, stacked_client_params, w, batch, step_idx):
        loss, grads = jax.value_and_grad(coboost_distill_loss)(
            server_params, stacked_client_params, w, cfg, batch, temperature, kl_chunk
        )
        if tc.grad_dtype:
            grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.dtype(tc.grad_dtype)), grads)
        if tc.grad_clip_norm > 0:
            grads = clip_by_global_norm(grads, tc.grad_clip_norm)
        updates, opt_state = opt.update(grads, opt_state, server_params, step_idx)
        server_params = apply_updates(server_params, updates)
        return server_params, opt_state, {"kd": loss}

    step.optimizer = opt
    return step


def make_prefill_step(cfg) -> Callable:
    def step(params, batch, state):
        return lm_prefill(params, cfg, batch, state)

    return step


def make_decode_step(cfg) -> Callable:
    def step(params, token, state, pos):
        return lm_decode(params, cfg, token, state, pos)

    return step

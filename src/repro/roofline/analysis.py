"""Three-term roofline model from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` reports *per-device* flops/bytes of the SPMD
module, so ``flops_per_device = HLO_FLOPs / chips`` already — the terms
below divide per-device quantities by per-chip rates (algebraically the
same as the global formulas). Collective bytes are NOT in cost_analysis:
we parse the post-partitioning HLO text and sum the output-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (per-device sizes; a documented proxy for
link traffic — e.g. a ring all-gather moves (n−1)/n of the output per
link, which we absorb into the single-link-bandwidth constant).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import numpy as np

# TPU v5e per-chip constants (assignment sheet)
@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    link_bw: float = 50e9  # bytes/s per ICI link
    hbm_bytes: float = 16 * 1024**3


V5E = HardwareSpec()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one HLO instruction:  %x = f32[8,128]{1,0} all-gather(...)   or tuple types
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every tensor in an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per-device) summed over the module.
    ``-start`` variants are counted; ``-done`` twins are skipped to avoid
    double counting."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        type_str, op = m.groups()
        base = op
        if base.endswith("-start"):
            base = base[: -len("-start")]
        elif base.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            out[base] += _shape_bytes(type_str)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (fwd-only), the
    "useful" compute yardstick. D = tokens processed this step."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def roofline_report(
    compiled,
    num_chips: int,
    cfg=None,
    shape=None,
    hw: HardwareSpec = V5E,
    hlo_text: Optional[str] = None,
) -> Dict[str, Any]:
    """Derive the three roofline terms (+ memory fit + useful-FLOPs ratio)
    from a compiled dry-run artifact.

    XLA's ``cost_analysis`` counts while-loop bodies ONCE (verified: an
    8-step scan of a 256³ matmul reports one iteration), so flops/bytes/
    collectives come from :class:`repro.roofline.hlo_cost.HloCost`, which
    walks the post-SPMD HLO text and scales every loop body by its static
    trip count. The raw cost_analysis numbers are retained for reference.
    """
    from repro.roofline.hlo_cost import HloCost

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    walk = HloCost(text).totals()
    flops_dev = float(walk["flops"])
    bytes_dev = float(walk["bytes"])
    coll = {k: int(walk[k]) for k in _COLLECTIVES}
    coll["total"] = int(walk["coll_total"])

    compute_s = flops_dev / hw.peak_flops
    memory_s = bytes_dev / hw.hbm_bw
    collective_s = coll["total"] / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        mem_info[attr] = int(getattr(mem, attr, 0) or 0)
    peak_bytes = (
        mem_info["argument_size_in_bytes"] + mem_info["temp_size_in_bytes"]
    )

    report: Dict[str, Any] = {
        "chips": num_chips,
        "hlo_flops_per_device": flops_dev,
        "hlo_flops_global": flops_dev * num_chips,
        "hlo_bytes_per_device": bytes_dev,
        "raw_cost_analysis_flops": float(cost.get("flops", 0.0)),  # while=1 caveat
        "collective_bytes_per_device": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "memory_analysis": mem_info,
        "peak_bytes_per_device": peak_bytes,
        "fits_hbm": peak_bytes <= hw.hbm_bytes,
    }
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        report["model_flops"] = mf
        global_flops = flops_dev * num_chips
        report["useful_flops_ratio"] = mf / global_flops if global_flops else 0.0
        # step-time bound and MFU if perfectly overlapped
        report["mfu_bound"] = (
            mf / (num_chips * hw.peak_flops) / terms[dominant] if terms[dominant] else 0.0
        )
    return report

"""Trip-count-aware cost accounting over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE
(verified empirically on this backend: an 8-step ``lax.scan`` of a 256³
matmul reports exactly one iteration's FLOPs). Every production model here
is scan-over-layers + scan-over-blocks, so raw cost_analysis undercounts by
1–2 orders of magnitude. This module re-derives per-device costs from the
compiled HLO text, recursively scaling each while body by its static trip
count (read from the ``constant(N)`` / ``compare direction=LT`` pattern in
the loop condition):

  * flops            — 2·|out|·|contraction| per ``dot``; conv via output
                       × window (the only two MXU ops we emit);
  * traffic bytes    — Σ (operand + output bytes) over materializing
                       instructions (fusions, dots, copies, slices,
                       collectives, reduces); GTE/bitcast/tuple/param are
                       free. Post-fusion, fusion boundaries ≈ HBM buffers,
                       so this is a reasonable per-device HBM-traffic proxy.
  * collective bytes — output bytes per collective kind (…-start counted,
                       …-done skipped).

Everything is per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction split: "%name = <type> op(rest" — the type may be a long tuple
# containing "/*index=N*/" comments (which contain '='), so split on the
# FIRST " = " and then locate the op as the first "word(" in the rhs (types
# never contain parens-after-word; dims use brackets/braces).
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+) = (.*)$")
_OP_RE = re.compile(r"([A-Za-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")

_FREE_OPS = {
    "get-tuple-element", "tuple", "bitcast", "parameter", "constant",
    "after-all", "opt-barrier", "partition-id", "replica-id", "iota",
}


def _shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dtype, dims in _shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


class _Instr:
    __slots__ = ("name", "type_str", "op", "rest")

    def __init__(self, name, type_str, op, rest):
        self.name, self.type_str, self.op, self.rest = name, type_str, op, rest


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.instrs: List[_Instr] = []
        self.shapes: Dict[str, str] = {}  # instr name -> type str

    def sliced_params(self) -> Dict[int, int]:
        """Fused computations that dynamic-slice a parameter read only the
        slice, not the whole operand. Returns {param_index: slice_bytes}."""
        # param name -> index
        pidx: Dict[str, int] = {}
        for ins in self.instrs:
            if ins.op == "parameter":
                m = re.match(r"\s*(\d+)\)", ins.rest)
                if m:
                    pidx[ins.name] = int(m.group(1))
        out: Dict[int, int] = {}
        for ins in self.instrs:
            if ins.op in ("dynamic-slice", "gather"):
                ops = re.findall(r"%([\w.\-]+)", ins.rest)
                if ops and ops[0] in pidx:
                    out[pidx[ops[0]]] = _bytes_of(ins.type_str)
        return out

    def find_const(self) -> Optional[int]:
        """Trip count from a loop-condition computation: the s32 constant
        compared with direction=LT (fused or direct)."""
        consts = []
        has_lt = False
        for ins in self.instrs:
            if ins.op == "constant" and ins.type_str.strip().startswith("s32"):
                m = re.search(r"constant\((\-?\d+)\)", "constant(" + ins.rest)
                if m:
                    consts.append(int(m.group(1)))
            if "direction=LT" in ins.rest or ins.op == "compare":
                has_lt = True
            if ins.op == "fusion" and "compare" in ins.rest:
                has_lt = True
        if consts:
            return max(consts)  # counters start at 0; LT bound == trip count
        return None


def parse_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and "{" in line:
                cur = _Computation(m.group(1))
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_HEAD_RE.match(line)
        if m:
            name, rhs = m.groups()
            mo = _OP_RE.search(rhs)
            if not mo:
                continue
            ins = _Instr(name, rhs[: mo.start()], mo.group(1), rhs[mo.end() :])
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.type_str
    return comps


def _dot_flops(ins: _Instr, comp: _Computation, comps: Dict[str, _Computation]) -> float:
    out_shapes = _shapes(ins.type_str)
    if not out_shapes:
        return 0.0
    out_n = 1
    for d in out_shapes[0][1]:
        out_n *= d
    # contraction size from the lhs operand's shape
    ops = re.findall(r"%([\w.\-]+)", ins.rest)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contract = 1
    if ops and m and ops[0] in comp.shapes:
        lhs_shapes = _shapes(comp.shapes[ops[0]])
        if lhs_shapes:
            dims = lhs_shapes[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2.0 * out_n * contract


def _conv_flops(ins: _Instr, comp: _Computation) -> float:
    out_shapes = _shapes(ins.type_str)
    if not out_shapes:
        return 0.0
    out_n = 1
    for d in out_shapes[0][1]:
        out_n *= d
    ops = re.findall(r"%([\w.\-]+)", ins.rest)
    kn = 1
    if len(ops) >= 2 and ops[1] in comp.shapes:
        ksh = _shapes(comp.shapes[ops[1]])
        if ksh:
            for d in ksh[0][1]:
                kn *= d
    return 2.0 * out_n * kn  # ≈ 2 · outputs · kernel elements (best effort)


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self._memo: Dict[str, Dict[str, float]] = {}
        entry = None
        for name, c in self.comps.items():
            if any(i.op == "while" for i in c.instrs) or name.startswith("main"):
                entry = entry or name
        # entry = the computation named main.* if present
        mains = [n for n in self.comps if n.startswith("main")]
        self.entry = mains[0] if mains else next(iter(self.comps))

    def _cost_of(self, comp_name: str) -> Dict[str, float]:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        zero = {"flops": 0.0, "bytes": 0.0, "coll_total": 0.0}
        zero.update({k: 0.0 for k in _COLLECTIVES})
        if comp is None:
            return zero
        total = dict(zero)
        self._memo[comp_name] = total  # guard cycles
        for ins in comp.instrs:
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if ins.op.endswith("-done"):
                continue
            if ins.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                trip = 1
                if mc and mc.group(1) in self.comps:
                    t = self.comps[mc.group(1)].find_const()
                    if t and t > 0:
                        trip = t
                if mb:
                    sub = self._cost_of(mb.group(1))
                    for k in total:
                        total[k] += trip * sub[k]
                continue
            if ins.op in ("fusion", "call", "custom-call", "conditional"):
                callees = re.findall(r"(?:calls|to_apply)=%([\w.\-]+)", ins.rest)
                mbr = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
                if mbr:
                    callees += re.findall(r"%([\w.\-]+)", mbr.group(1))
                for cname in callees:
                    if cname in self.comps:
                        sub = self._cost_of(cname)
                        for k in total:
                            if k != "bytes":  # fused internals don't touch HBM
                                total[k] += sub[k]
            if ins.op == "dot":
                total["flops"] += _dot_flops(ins, comp, self.comps)
            elif ins.op == "convolution":
                total["flops"] += _conv_flops(ins, comp)
            if base in _COLLECTIVES:
                b = _bytes_of(ins.type_str)
                total[base] += b
                total["coll_total"] += b
            if ins.op not in _FREE_OPS and ins.op != "while":
                out_b = _bytes_of(ins.type_str)
                operand_names = re.findall(r"%([\w.\-]+)", ins.rest)
                if ins.op in ("dynamic-slice", "gather"):
                    # reads only the slice (≈ output) from the big operand
                    total["bytes"] += 2 * out_b
                    continue
                if ins.op == "dynamic-update-slice":
                    # writes only the update region (operand 1) in place
                    upd = 0
                    if len(operand_names) > 1 and operand_names[1] in comp.shapes:
                        upd = _bytes_of(comp.shapes[operand_names[1]])
                    total["bytes"] += 2 * (upd or out_b)
                    continue
                in_b = 0
                sliced: Dict[int, int] = {}
                if ins.op == "fusion":
                    mcall = re.search(r"calls=%([\w.\-]+)", ins.rest)
                    if mcall and mcall.group(1) in self.comps:
                        sliced = self.comps[mcall.group(1)].sliced_params()
                for i, opname in enumerate(operand_names):
                    if opname in comp.shapes:
                        if i in sliced:
                            in_b += sliced[i]  # fused dynamic-slice of operand i
                        else:
                            in_b += _bytes_of(comp.shapes[opname])
                total["bytes"] += out_b + in_b
        self._memo[comp_name] = total
        return total

    def totals(self) -> Dict[str, float]:
        return self._cost_of(self.entry)

from repro.roofline.analysis import (
    V5E,
    HardwareSpec,
    collective_bytes,
    roofline_report,
    model_flops,
)

__all__ = ["V5E", "HardwareSpec", "collective_bytes", "roofline_report", "model_flops"]

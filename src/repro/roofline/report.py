"""Format dry-run JSON records into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun_single.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def _fmt_b(x: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def markdown_table(records: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute | memory | collective | dominant | useful-FLOPs | HBM/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | SKIP: {r['reason']} | — | — | — |"
            )
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | {r['error'][:60]} | | | | | |")
            continue
        ratio = r.get("useful_flops_ratio", 0.0)
        lines.append(
            "| {arch} | {shape} | {mesh} | {c} | {m} | {k} | **{dom}** | {ratio:.2f} | {hbm} | {fits} |".format(
                arch=r["arch"],
                shape=r["shape"],
                mesh=r["mesh"],
                c=_fmt_s(r["compute_s"]),
                m=_fmt_s(r["memory_s"]),
                k=_fmt_s(r["collective_s"]),
                dom=r["dominant"],
                ratio=ratio,
                hbm=_fmt_b(r["peak_bytes_per_device"]),
                fits="✓" if r["fits_hbm"] else "✗",
            )
        )
    return "\n".join(lines)


def collective_breakdown(records: List[Dict]) -> str:
    lines = [
        "| arch | shape | all-gather | all-reduce | reduce-scatter | all-to-all | permute |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            continue
        c = r["collectives"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_b(c['all-gather'])} | {_fmt_b(c['all-reduce'])} "
            f"| {_fmt_b(c['reduce-scatter'])} | {_fmt_b(c['all-to-all'])} | {_fmt_b(c['collective-permute'])} |"
        )
    return "\n".join(lines)


def main() -> None:
    records: List[Dict] = []
    for path in sys.argv[1:]:
        with open(path) as f:
            records.extend(json.load(f))
    print("### Roofline terms (one step, per the three-term model)\n")
    print(markdown_table(records))
    print("\n### Collective-bytes breakdown (per device, per step)\n")
    print(collective_breakdown([r for r in records if r.get("mesh") == "16x16"]))


if __name__ == "__main__":
    main()

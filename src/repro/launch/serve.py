"""Serving driver for the distilled server LM: continuous-batching fleet
(default) or the fused static-batch baseline.

    # continuous batching: staggered requests through the slot engine
    # (paged KV pool + flash-decode by default; --kv-layout dense for the
    # per-slot-rectangle SDPA baseline)
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --engine continuous --requests 8 --request-rate 20 --max-slots 4 \
        --page-size 16 --pool-pages 0

    # serving FLEET: N engine replicas behind the least-loaded router, each
    # replica optionally a disaggregated prefill/decode pair on disjoint
    # mesh halves (needs >= 2 devices per replica to actually split)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --engine continuous --replicas 2 --disagg --requests 8

    # static baseline: one batch, prefill + single-dispatch decode
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --reduced --engine static --batch 4 --prompt-len 64 --gen 32

Argument validation fails fast — encoder-only archs, vlm continuous
serving, ``--disagg`` with the dense KV layout, and unsupported static mesh
shapes are rejected with a clear message BEFORE any device allocation, and
the exact fleet EngineConfig/KVPool pair is dry-constructed pre-device.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.config import get_arch, reduced_variant
from repro.data import make_token_stream
from repro.launch.mesh import (
    disagg_submeshes,
    make_fleet_mesh,
    make_host_mesh,
    make_production_mesh,
    mesh_context,
    replica_meshes,
)
from repro.models import group_pattern, init_lm
from repro.serve import (
    ContinuousScheduler,
    EngineConfig,
    FleetRouter,
    KVPool,
    Request,
    ServeEngine,
    hot_prefix_stream,
    latency_summary,
    static_generate,
)
from repro.kernels import policy_from_flags
from repro.utils import get_logger

log = get_logger("serve")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--engine", default="continuous", choices=("continuous", "static"))
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--mesh", default="host", choices=("host", "production", "multipod"))
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--backend", default=None,
                   choices=("auto", "pallas", "pallas-interpret", "ref"),
                   help="kernel backend for every dispatched op (attn + decode)")
    p.add_argument("--attn-backend", default=None,
                   choices=("auto", "pallas", "pallas-interpret", "ref"),
                   help="DEPRECATED: use --backend (this alias sets only the attn op)")
    # static arm
    p.add_argument("--batch", type=int, default=4)
    # continuous arm
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--request-rate", type=float, default=0.0,
                   help="arrivals per second (0 = all at t=0)")
    p.add_argument("--max-slots", type=int, default=4)
    p.add_argument("--decode-chunk", type=int, default=8)
    # fleet topology (continuous arm)
    p.add_argument("--replicas", type=int, default=1,
                   help="engine replicas behind the least-loaded router "
                        "(each replica shards over its own mesh slice)")
    p.add_argument("--disagg", action="store_true",
                   help="split each replica into a disaggregated prefill/decode "
                        "worker pair (paged KV layout only; the pair colocates "
                        "on a single-device replica)")
    # prefix cache + speculative decoding (continuous arm)
    p.add_argument("--prefix-cache", action="store_true",
                   help="radix prefix cache over refcounted KV pages: hot "
                        "admissions splice resident prompt pages and prefill "
                        "only the uncovered tail (paged layout only)")
    p.add_argument("--spec-decode", action="store_true",
                   help="speculative decoding: a small drafter proposes "
                        "--spec-k tokens per step, the target verifies them "
                        "in one batched forward (greedy/temperature 0 only)")
    p.add_argument("--drafter", default="smollm-135m",
                   help="registry arch drafting for --spec-decode (reduced "
                        "alongside --reduced; must share the target's vocab "
                        "and be attention-only with a full cache)")
    p.add_argument("--spec-k", type=int, default=4,
                   help="draft tokens per verify step for --spec-decode")
    p.add_argument("--hot-fraction", type=float, default=0.0,
                   help="fraction of requests sharing a hot prompt prefix "
                        "(exercises --prefix-cache; 0 = fully cold traffic)")
    # paged KV pool (continuous arm)
    p.add_argument("--kv-layout", default="paged", choices=("paged", "dense"),
                   help="paged: KVPool + flash-decode; dense: per-slot rectangle + SDPA")
    p.add_argument("--page-size", type=int, default=16,
                   help="tokens per KV page (power of two)")
    p.add_argument("--pool-pages", type=int, default=0,
                   help="KV pool capacity in pages (0 = full per-slot capacity)")
    p.add_argument("--decode-backend", default=None,
                   choices=("auto", "pallas", "pallas-interpret", "ref"),
                   help="DEPRECATED: use --backend (this alias sets only the "
                        "paged decode op)")
    # telemetry (repro.obs) — off by default, zero-cost when off
    p.add_argument("--metrics-out", default=None, metavar="PATH.jsonl",
                   help="dump the metrics registry as JSONL (plus a .prom "
                        "Prometheus-text sibling) at exit; also routes every "
                        "replica's stats into one shared registry with "
                        "replica labels")
    p.add_argument("--trace-out", default=None, metavar="PATH.json",
                   help="record host-side spans (route/admit/prefill/handoff/"
                        "decode-chunk/...) and dump Chrome trace-event JSON "
                        "(Perfetto-loadable) at exit")
    p.add_argument("--profile-dir", default=None,
                   help="also run a JAX profiler trace into this directory, "
                        "bridging every span to a TraceAnnotation so host "
                        "and device timelines line up")
    return p


def _finalize_telemetry(args, engines=()) -> None:
    """Publish end-of-run KV/prefix gauges and dump the artifacts the flags
    asked for (the validator in :mod:`repro.obs.validate` gates them in CI)."""
    for eng in engines:
        eng.publish_gauges()
    if args.profile_dir:
        obs.stop_jax_profile(obs.tracer())
    if args.metrics_out:
        obs.registry().dump(args.metrics_out)
        log.info("metrics snapshot -> %s (+ .prom)", args.metrics_out)
    if args.trace_out:
        obs.tracer().dump(args.trace_out)
        log.info("trace -> %s (%d events)", args.trace_out, len(obs.tracer()))


def _effective_replicas(args) -> int:
    """``--mesh multipod`` serves one fleet per pod: a decode engine is a
    single-pod program (the pod axis is a DCN boundary), so each of the two
    pods carries its own replica group behind the shared router."""
    return args.replicas * (2 if args.mesh == "multipod" else 1)


def validate_args(args, cfg) -> None:
    """Fail fast, with a clear message, before any device allocation."""
    if cfg.is_encoder_only:
        raise SystemExit(
            f"{cfg.name} is encoder-only: no autoregressive decode, nothing to "
            "serve (DESIGN.md skip). Pick a decoder arch."
        )
    if args.mesh == "multipod" and args.engine == "static":
        raise SystemExit(
            "--mesh multipod is not supported for static serving: the fused "
            "static program is single-pod (the pod axis is data-parallel "
            "replication). Use --engine continuous, which runs one engine "
            "replica group per pod behind the fleet router."
        )
    if args.prompt_len < 1 or args.gen < 1:
        raise SystemExit(f"--prompt-len ({args.prompt_len}) and --gen ({args.gen}) must be >= 1")
    if args.engine == "static" and args.batch < 1:
        raise SystemExit(f"--batch must be >= 1, got {args.batch}")
    if args.replicas < 1:
        raise SystemExit(f"--replicas must be >= 1, got {args.replicas}")
    if (args.replicas > 1 or args.disagg) and args.engine != "continuous":
        raise SystemExit(
            "--replicas/--disagg describe the continuous serving fleet; the "
            "static baseline is a single fused program. Use --engine continuous."
        )
    if args.engine == "continuous":
        if cfg.frontend == "vision":
            raise SystemExit(
                f"{cfg.name} is a vlm: the continuous engine does not thread "
                "per-request vision prefix embeddings through admission yet — "
                "use --engine static (which feeds the prefix at prefill)."
            )
        if args.max_slots < 1:
            raise SystemExit(f"--max-slots must be >= 1, got {args.max_slots}")
        if args.requests < 1:
            raise SystemExit(f"--requests must be >= 1, got {args.requests}")
        if args.request_rate < 0:
            raise SystemExit(f"--request-rate must be >= 0, got {args.request_rate}")
        if args.decode_chunk < 1:
            raise SystemExit(f"--decode-chunk must be >= 1, got {args.decode_chunk}")
        if args.kv_layout == "paged" and args.pool_pages < 0:
            raise SystemExit(f"--pool-pages must be >= 0, got {args.pool_pages}")
        if args.disagg and args.kv_layout == "dense":
            raise SystemExit(
                '--disagg requires --kv-layout paged: the prefill->decode '
                "handoff moves sealed KV PAGES between worker pools, and the "
                "dense per-slot rectangle has no page units to hand off."
            )
        if args.prefix_cache and args.kv_layout == "dense":
            raise SystemExit(
                "--prefix-cache requires --kv-layout paged: prefix sharing IS "
                "page-table splicing — the dense per-slot rectangle has no "
                "page units to share."
            )
        if not 0.0 <= args.hot_fraction <= 1.0:
            raise SystemExit(f"--hot-fraction must be in [0, 1], got {args.hot_fraction}")
        if args.spec_decode:
            if args.spec_k < 1:
                raise SystemExit(f"--spec-k must be >= 1, got {args.spec_k}")
            if args.kv_layout != "paged":
                raise SystemExit(
                    "--spec-decode requires --kv-layout paged: the batched "
                    "verify is an extend over the page-table cache view."
                )
            if args.temperature > 0.0:
                raise SystemExit(
                    "--spec-decode requires --temperature 0: the accept-"
                    "longest-greedy-run verify is a greedy parity contract."
                )
            dcfg = _drafter_config(args)
            non_attn = sorted({m for m, _ in group_pattern(dcfg) if m != "attn"})
            if non_attn:
                raise SystemExit(
                    f"--drafter {dcfg.name} has {non_attn} mixers: a recurrent "
                    "carry cannot roll back past a rejected draft. Draft with "
                    "an attention-only arch."
                )
            if dcfg.sliding_window > 0:
                raise SystemExit(
                    f"--drafter {dcfg.name} uses a sliding window "
                    f"({dcfg.sliding_window}): the ring cache cannot roll back "
                    "rejected drafts (stale writes alias earlier positions). "
                    "Draft with a full-attention arch."
                )
            if dcfg.vocab_size != cfg.vocab_size:
                raise SystemExit(
                    f"--drafter {dcfg.name} vocab ({dcfg.vocab_size}) does not "
                    f"match {cfg.name} ({cfg.vocab_size}): drafted token ids "
                    "would be meaningless to the verifier."
                )
        # dry-construct the exact EngineConfig (and, for the paged layout,
        # the KVPool — which bills the pool floor against the MODEL's cache
        # length) that every fleet replica will build: both are pure-host,
        # so the full paged consistency matrix (including disagg) dies HERE,
        # not after init_lm
        try:
            ecfg = _continuous_engine_config(args)
            has_attn = any(m == "attn" for m, _ in group_pattern(cfg))
            if args.disagg and not has_attn:
                raise ValueError(
                    f"{cfg.name} has no attention layers: its serving state "
                    "degrades to the dense layout, which has no page units to "
                    "hand off — --disagg needs an attention arch."
                )
            if args.kv_layout == "paged" and has_attn:  # pure-SSM runs dense
                KVPool(cfg, ecfg)
        except ValueError as ex:
            raise SystemExit(str(ex))


def run_static(args, cfg, params) -> None:
    data = make_token_stream(args.seed, cfg.vocab_size, args.batch, args.prompt_len)
    batch = {"tokens": jnp.asarray(data["tokens"][:, : args.prompt_len])}
    if cfg.family == "vlm":
        rng = np.random.RandomState(args.seed)
        batch["prefix"] = jnp.asarray(
            rng.randn(args.batch, cfg.num_prefix_tokens, cfg.frontend_dim).astype(np.float32) * 0.02
        )
    # compile, then time: prefill + whole decode is ONE dispatch; tokens
    # accumulate on device (no per-token host sync) and cross once at the end
    gen_fn = lambda: static_generate(
        params, cfg, batch, args.gen, temperature=args.temperature,
        key=jax.random.key(args.seed),
    )
    jax.block_until_ready(gen_fn())
    t0 = time.time()
    out = np.asarray(gen_fn())
    dt = time.time() - t0
    toks = args.batch * args.gen
    log.info("static: %d tokens in %.3fs (%.1f tok/s, 1 dispatch)", toks, dt, toks / max(dt, 1e-9))
    log.info("sample continuation (seq 0): %s", out[0, :16].tolist())
    _finalize_telemetry(args)


def _drafter_config(args):
    """The drafter ModelConfig for --spec-decode: reduced alongside the
    target (a full-size drafter against a reduced target would be slower
    than the thing it accelerates)."""
    dcfg = get_arch(args.drafter)
    if args.reduced:
        dcfg = reduced_variant(dcfg).replace(dtype="float32", param_dtype="float32")
    return dcfg


def _continuous_engine_config(args) -> EngineConfig:
    max_seq = args.prompt_len + args.gen
    if args.kv_layout == "paged":
        # the page-table extent must recover the logical cache length exactly
        max_seq = -(-max_seq // args.page_size) * args.page_size
    return EngineConfig(
        max_slots=args.max_slots,
        max_seq=max_seq,
        max_new=args.gen,
        decode_chunk=args.decode_chunk,
        temperature=args.temperature,
        seed=args.seed,
        kv_layout=args.kv_layout,
        page_size=args.page_size,
        pool_pages=args.pool_pages,
        disagg=args.disagg,
        prefix_cache=args.prefix_cache,
        spec_k=args.spec_k if args.spec_decode else 0,
    )


def build_fleet(args, cfg, params) -> list:
    """Construct the engine replicas. With more than one device the fleet
    mesh splits them ``replicas × (data=1) × model`` and each engine shards
    over its slice (``--disagg`` further halves a slice into the prefill and
    decode workers' submeshes); on one device the replicas colocate meshless
    (distinct pools and programs, shared device) — same topology, same
    router, degenerate placement."""
    replicas = _effective_replicas(args)
    ecfg = _continuous_engine_config(args)
    drafter = None
    if args.spec_decode:
        dcfg = _drafter_config(args)
        drafter = (dcfg, init_lm(dcfg, jax.random.key(args.seed + 1)))
    n_dev = len(jax.devices())
    if n_dev > 1 and replicas > 1:
        subs = replica_meshes(make_fleet_mesh(replicas))
    else:
        subs = [None] * replicas
    # with --metrics-out every replica's stats land in the process-global
    # registry under its replica label (one snapshot for the whole fleet);
    # without it each engine keeps its private always-on registry
    registry = obs.registry() if args.metrics_out else None
    engines = []
    for i, sub in enumerate(subs):
        pmesh = dmesh = sub
        if args.disagg and sub is not None:
            pmesh, dmesh = disagg_submeshes(sub)
        engines.append(
            ServeEngine(
                cfg, params, ecfg, mesh=dmesh, prefill_mesh=pmesh, drafter=drafter,
                registry=registry, replica=i,
            )
        )
    return engines


def run_continuous(args, cfg, params) -> None:
    dt = 1.0 / args.request_rate if args.request_rate > 0 else 0.0
    if args.hot_fraction > 0:
        prompts, _ = hot_prefix_stream(
            cfg.vocab_size, args.requests, args.prompt_len, args.gen,
            seed=args.seed, shared_fraction=args.hot_fraction,
        )
    else:
        data = make_token_stream(args.seed, cfg.vocab_size, args.requests, args.prompt_len)
        prompts = [
            data["tokens"][i, : args.prompt_len].astype(np.int32)
            for i in range(args.requests)
        ]
    requests = [
        Request(rid=i, tokens=p, max_new_tokens=args.gen, arrival=i * dt)
        for i, p in enumerate(prompts)
    ]
    engines = build_fleet(args, cfg, params)
    sched = (
        ContinuousScheduler(engines[0]) if len(engines) == 1 else FleetRouter(engines)
    )
    # compile every admit size + the chunk program on every replica before
    # timing (replicas over identical mesh slices share the compile cache)
    for eng in engines:
        eng.warmup(requests[0].tokens, min(2, args.gen))
    t0 = time.time()
    completions = sched.run(requests)
    wall = time.time() - t0
    # one summary shape for every path — the N=1 ContinuousScheduler run
    # reports the same queue-wait split the fleet always has (the deferral
    # latency a single tight engine causes is just as real as a router's)
    s = latency_summary(completions, wall)
    log.info(
        "fleet[%d%s]: %d reqs, %d tokens in %.3fs (%.1f tok/s) "
        "p50=%.3fs p95=%.3fs queue-wait p50=%.3fs p95=%.3fs",
        len(engines), "+disagg" if args.disagg else "",
        len(completions), int(s["tokens"]), wall, s["tok_per_s"],
        s["p50_s"], s["p95_s"], s["queue_wait_p50_s"], s["queue_wait_p95_s"],
    )
    for i, eng in enumerate(engines):
        served = sum(1 for c in completions if c.replica == i)
        log.info(
            "replica %d: %d reqs, %d decode chunks, %d host syncs, %d prefills, "
            "%d handoffs",
            i, served, eng.stats["decode_chunks"], eng.stats["host_syncs"],
            eng.stats["prefill_dispatches"], eng.stats["handoffs"],
        )
        if eng.pool is not None:
            log.info(
                "replica %d kv pool: %d pages x %d tokens (%s layout), "
                "%d decode-time appends",
                i, eng.pool.n_pages, eng.pool.page_size, eng.layout,
                eng.stats["page_appends"],
            )
        if args.prefix_cache:
            admitted = max(eng.stats["admitted"], 1)
            log.info(
                "replica %d prefix cache: %d/%d admissions spliced "
                "(hit rate %.0f%%), %d pages reused, %d CoW copies",
                i, eng.stats["spliced_admissions"], eng.stats["admitted"],
                100.0 * eng.stats["spliced_admissions"] / admitted,
                eng.stats["spliced_pages"], eng.stats["cow_copies"],
            )
        if args.spec_decode:
            proposed = max(eng.stats["draft_proposed"], 1)
            log.info(
                "replica %d spec decode: %d verify steps, %d/%d drafts "
                "accepted (%.0f%%)",
                i, eng.stats["spec_steps"], eng.stats["draft_accepted"],
                eng.stats["draft_proposed"],
                100.0 * eng.stats["draft_accepted"] / proposed,
            )
    if isinstance(sched, FleetRouter) and len(engines) > 1:
        log.info(
            "router: %d routed, %d requeued-on-defer, %d prefix-affinity hits",
            sched.stats["routed"], sched.stats["requeued"],
            sched.stats["affinity_hits"],
        )
    log.info("sample continuation (rid 0): %s", completions[0].tokens[:16].tolist())
    _finalize_telemetry(args, engines)


def main() -> None:
    args = build_parser().parse_args()
    cfg = get_arch(args.arch)
    if args.reduced:
        # reduce BEFORE validating: the paged-pool floor bills against the
        # model's actual cache length (a reduced variant clamps the window)
        cfg = reduced_variant(cfg).replace(dtype="float32", param_dtype="float32")
    validate_args(args, cfg)  # before any device/mesh work
    obs.configure(
        metrics=bool(args.metrics_out),
        trace=bool(args.trace_out),
        profile_dir=args.profile_dir,
    )
    cfg = cfg.replace(backend=policy_from_flags(
        backend=args.backend,
        attn_backend=args.attn_backend,
        decode_backend=args.decode_backend,
    ))
    fleet = args.engine == "continuous" and (
        _effective_replicas(args) > 1 or args.disagg
    )
    if fleet:
        # no global mesh context: each replica shards params/state against
        # ITS submesh explicitly (a context mesh with a replica axis would
        # leak into init-time sharding constraints)
        params = init_lm(cfg, jax.random.key(args.seed))
        run_continuous(args, cfg, params)
        return
    mesh = {"host": make_host_mesh, "production": make_production_mesh}[args.mesh]()
    with mesh_context(mesh):
        params = init_lm(cfg, jax.random.key(args.seed))
        if args.engine == "static":
            run_static(args, cfg, params)
        else:
            run_continuous(args, cfg, params)


if __name__ == "__main__":
    main()

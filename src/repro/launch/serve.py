"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --reduced --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced_variant
from repro.data import make_token_stream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_lm, init_lm_state, lm_decode, lm_prefill
from repro.utils import get_logger

log = get_logger("serve")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-3-2b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--mesh", default="host", choices=("host", "production", "multipod"))
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_arch(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode (DESIGN.md skip)")
    if args.reduced:
        cfg = reduced_variant(cfg).replace(dtype="float32", param_dtype="float32")
    mesh = {
        "host": make_host_mesh,
        "production": make_production_mesh,
        "multipod": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]()

    max_seq = args.prompt_len + args.gen
    with jax.set_mesh(mesh):
        params = init_lm(cfg, jax.random.key(args.seed))
        data = make_token_stream(args.seed, cfg.vocab_size, args.batch, args.prompt_len)
        batch = {"tokens": jnp.asarray(data["tokens"])}
        if cfg.family == "vlm":
            rng = np.random.RandomState(args.seed)
            batch["prefix"] = jnp.asarray(
                rng.randn(args.batch, cfg.num_prefix_tokens, cfg.frontend_dim).astype(np.float32) * 0.02
            )
        state = init_lm_state(cfg, args.batch, max_seq + cfg.num_prefix_tokens)

        prefill = jax.jit(lambda p, b, s: lm_prefill(p, cfg, b, s))
        decode = jax.jit(lambda p, t, s, pos: lm_decode(p, cfg, t, s, pos))

        t0 = time.time()
        logits, state = prefill(params, batch, state)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        log.info("prefill %d×%d tokens in %.2fs", args.batch, args.prompt_len, t_prefill)

        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated = [np.asarray(tok)]
        t0 = time.time()
        base = args.prompt_len + cfg.num_prefix_tokens
        for i in range(args.gen - 1):
            logits, state = decode(params, tok, state, jnp.asarray(base + i, jnp.int32))
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            generated.append(np.asarray(tok))
        jax.block_until_ready(tok)
        dt = time.time() - t0
        toks = args.batch * (args.gen - 1)
        log.info("decoded %d tokens in %.2fs (%.1f tok/s)", toks, dt, toks / max(dt, 1e-9))
        out = np.concatenate(generated, axis=1)
        log.info("sample continuation (seq 0): %s", out[0, :16].tolist())


if __name__ == "__main__":
    main()

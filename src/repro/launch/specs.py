"""ShapeDtypeStruct input specs + jit program builders for every
(architecture × input shape) combination.

``input_specs`` produces weak-type-correct, shardable stand-ins for every
model input — no device allocation ever happens; params come from
``jax.eval_shape`` over the real initializer. ``build_program`` returns
(fn, arg_specs, in_shardings, out_shardings) ready for
``jax.jit(fn, ...).lower(*args).compile()`` under a mesh context.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.models import init_lm, init_lm_state
from repro.runtime import make_decode_step, make_prefill_step, make_train_step
from repro.sharding import decode_state_specs, infer_param_specs, resolve_rule
from repro.sharding.partition import _mesh_axes

SDS = jax.ShapeDtypeStruct

# dry-run trainer: the paper's own optimizer (SGD momentum, App. B.1) — one
# f32 slot; this is also what keeps the 235B MoE inside 16 GB/chip.
DRYRUN_TC = TrainConfig(optimizer="sgdm", learning_rate=0.01, momentum=0.9)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    """Model-input stand-ins for one input shape. Training/prefill get the
    full sequence; decode gets ONE token (the KV cache carries seq_len)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": SDS((b, 1), jnp.int32)}
    if cfg.family == "audio":
        return {
            "frames": SDS((b, s, cfg.frontend_dim), jnp.float32),
            "labels": SDS((b, s), jnp.int32),
        }
    if cfg.family == "vlm":
        p = cfg.num_prefix_tokens
        return {
            "tokens": SDS((b, s - p), jnp.int32),
            "prefix": SDS((b, p, cfg.frontend_dim), jnp.float32),
            "labels": SDS((b, s - p), jnp.int32),
        }
    batch = {"tokens": SDS((b, s), jnp.int32), "labels": SDS((b, s), jnp.int32)}
    if shape.kind == "prefill":
        del batch["labels"]
    return batch


def param_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(partial(init_lm, cfg), jax.random.key(0))


def state_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    return jax.eval_shape(
        partial(init_lm_state, cfg, shape.global_batch, shape.seq_len)
    )


def _named(tree_specs) -> Any:
    """PartitionSpec tree -> NamedSharding tree against the current mesh."""
    mesh = jax.sharding.get_mesh()
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_shardings(batch: Dict[str, SDS]) -> Dict[str, Any]:
    axes = _mesh_axes()
    out = {}
    for k, v in batch.items():
        if k in ("tokens", "labels") and v.ndim == 2:
            spec = resolve_rule(("batch", "seq"), v.shape, axes)
            if v.shape[1] == 1:  # decode token
                spec = P(spec[0], None)
        elif v.ndim == 3:
            spec = resolve_rule(("batch", "seq", None), v.shape, axes)
        else:
            spec = P(*([None] * v.ndim))
        out[k] = spec
    return _named(out)


def replicated(tree) -> Any:
    mesh = jax.sharding.get_mesh()
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def build_program(
    cfg: ModelConfig, shape: ShapeConfig, tc: TrainConfig = DRYRUN_TC
) -> Tuple[Callable, Tuple, Any, Any]:
    """Returns (fn, arg_specs, in_shardings, out_shardings) for the step
    this input shape exercises (train / prefill / decode)."""
    psds = param_specs(cfg)
    pspecs = infer_param_specs(psds)
    pshard = _named(pspecs)
    batch = input_specs(cfg, shape)
    bshard = batch_shardings(batch)

    if shape.kind == "train":
        step = make_train_step(cfg, tc)
        opt_sds = jax.eval_shape(step.optimizer.init, psds)
        oshard = _named(infer_param_specs(opt_sds))
        idx = SDS((), jnp.int32)
        args = (psds, opt_sds, batch, idx)
        in_sh = (pshard, oshard, bshard, NamedSharding(jax.sharding.get_mesh(), P()))
        metrics_sds = jax.eval_shape(step, *args)[2]
        out_sh = (pshard, oshard, replicated(metrics_sds))
        return step, args, in_sh, out_sh

    ssds = state_specs(cfg, shape)
    sshard = _named(decode_state_specs(ssds))
    mesh = jax.sharding.get_mesh()
    if shape.kind == "prefill":
        step = make_prefill_step(cfg)
        args = (psds, batch, ssds)
        in_sh = (pshard, bshard, sshard)
        logit_sh = NamedSharding(mesh, resolve_rule(("batch", None, "vocab"), (shape.global_batch, 1, cfg.vocab_size), _mesh_axes()))
        out_sh = (logit_sh, sshard)
        return step, args, in_sh, out_sh

    # decode
    step = make_decode_step(cfg)
    tok = batch["tokens"]
    pos = SDS((), jnp.int32)
    args = (psds, tok, ssds, pos)
    in_sh = (pshard, bshard["tokens"], sshard, NamedSharding(mesh, P()))
    logit_sh = NamedSharding(mesh, resolve_rule(("batch", None, "vocab"), (shape.global_batch, 1, cfg.vocab_size), _mesh_axes()))
    out_sh = (logit_sh, sshard)
    return step, args, in_sh, out_sh


def build_coboost_program(
    cfg: ModelConfig,
    shape: ShapeConfig,
    num_clients: int = 4,
    tc: TrainConfig = DRYRUN_TC,
    kl_chunk: int = 0,
) -> Tuple[Callable, Tuple, Any, Any]:
    """The paper-technique program at LM scale: one server-distillation step
    (Eq. 4) against a K-client stacked ensemble on synthetic embedding
    batches. This is the (most-representative) dry-run/hillclimb target."""
    from repro.runtime import make_distill_step_lm

    psds = param_specs(cfg)
    pspecs = infer_param_specs(psds)
    pshard = _named(pspecs)
    stacked_sds = jax.tree_util.tree_map(
        lambda x: SDS((num_clients, *x.shape), x.dtype), psds
    )
    # stacked client params shard like ordinary params (leading K dim is
    # padded with None by the divisibility-aware rules)
    stacked_shard = _named(infer_param_specs(stacked_sds))
    mesh = jax.sharding.get_mesh()
    axes = _mesh_axes()
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "embeds": SDS((b, s, cfg.d_model), jnp.float32),
        "labels": SDS((b, s), jnp.int32),  # unused by KL but keeps shapes uniform
    }
    bshard = {
        "embeds": NamedSharding(mesh, resolve_rule(("batch", "seq", None), (b, s, cfg.d_model), axes)),
        "labels": NamedSharding(mesh, resolve_rule(("batch", "seq"), (b, s), axes)),
    }
    step = make_distill_step_lm(cfg, tc, kl_chunk=kl_chunk)
    opt_sds = jax.eval_shape(step.optimizer.init, psds)
    oshard = _named(infer_param_specs(opt_sds))
    w_sds = SDS((num_clients,), jnp.float32)
    idx = SDS((), jnp.int32)
    args = (psds, opt_sds, stacked_sds, w_sds, batch, idx)
    rep = NamedSharding(mesh, P())
    in_sh = (pshard, oshard, stacked_shard, rep, bshard, rep)
    metrics_sds = jax.eval_shape(step, *args)[2]
    out_sh = (pshard, oshard, replicated(metrics_sds))
    return step, args, in_sh, out_sh

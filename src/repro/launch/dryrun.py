import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture × input shape) step program against
the production mesh — 16×16 single-pod and 2×16×16 two-pod — and records
memory_analysis / cost_analysis / collective schedule for the roofline.

The two lines above run BEFORE any other import: jax locks the device
count at first init, and only the dry-run is allowed to see 512 placeholder
CPU devices (smoke tests and benches see 1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax

from repro.config import INPUT_SHAPES, arch_supports_shape, get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_coboost_program, build_program
from repro.roofline import roofline_report
from repro.utils import get_logger

log = get_logger("dryrun")


def _parse_value(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    return v


def _custom_mesh(spec: str):
    dims = tuple(int(d) for d in spec.split("x"))
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    from repro.launch.mesh import compat_make_mesh

    return compat_make_mesh(dims, axes)


def dryrun_one(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    verbose: bool = True,
    coboost_clients: int = 0,
    cfg_override=None,
    overrides: Dict[str, Any] = None,
    tc_overrides: Dict[str, Any] = None,
    mesh_shape: str = "",
    kl_chunk: int = 0,
) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh) combination; returns the
    roofline record (or a skip/error record). ``coboost_clients > 0`` lowers
    the paper-technique ensemble-distillation step instead of the plain
    step. ``overrides``/``tc_overrides``/``mesh_shape``/``kl_chunk`` are the
    §Perf hillclimb levers."""
    from repro.launch.specs import DRYRUN_TC

    cfg = cfg_override if cfg_override is not None else get_arch(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    tc = DRYRUN_TC
    if tc_overrides:
        import dataclasses as _dc

        tc = _dc.replace(tc, **tc_overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = mesh_shape or ("2x16x16" if multi_pod else "16x16")
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if coboost_clients:
        rec["coboost_clients"] = coboost_clients
    if overrides:
        rec["overrides"] = overrides
    if tc_overrides:
        rec["tc_overrides"] = tc_overrides
    if kl_chunk:
        rec["kl_chunk"] = kl_chunk
    skip = arch_supports_shape(cfg, shape)
    if skip:
        rec.update(status="skip", reason=skip)
        return rec
    mesh = _custom_mesh(mesh_shape) if mesh_shape else make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with jax.set_mesh(mesh):
        if coboost_clients:
            fn, args, in_sh, out_sh = build_coboost_program(
                cfg, shape, coboost_clients, tc=tc, kl_chunk=kl_chunk
            )
        else:
            fn, args, in_sh, out_sh = build_program(cfg, shape, tc=tc)
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        hlo = compiled.as_text()
        report = roofline_report(compiled, mesh.size, cfg, shape, hlo_text=hlo)
    rec.update(
        status="ok",
        kind=shape.kind,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        **report,
    )
    if verbose:
        print(compiled.memory_analysis())
        print({k: v for k, v in compiled.cost_analysis().items() if k in ("flops", "bytes accessed")})
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="architecture id (see --list)")
    p.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    p.add_argument("--all", action="store_true", help="every (arch, shape) pair")
    p.add_argument("--multi-pod", default="single", choices=("single", "multi", "both"))
    p.add_argument("--out", default=None, help="append JSON records here")
    p.add_argument("--list", action="store_true")
    p.add_argument(
        "--coboost",
        type=int,
        default=0,
        metavar="K",
        help="lower the K-client Co-Boosting distillation step instead",
    )
    p.add_argument(
        "--override", action="append", default=[], metavar="K=V",
        help="ModelConfig field override (e.g. moe_impl=scatter)",
    )
    p.add_argument(
        "--tc-override", action="append", default=[], metavar="K=V",
        help="TrainConfig field override (e.g. state_dtype=bfloat16)",
    )
    p.add_argument("--mesh-shape", default="", help="custom mesh, e.g. 32x8 or 2x32x8")
    p.add_argument("--kl-chunk", type=int, default=0, help="chunked distill-KL (coboost)")
    args = p.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.override)
    overrides = {k: _parse_value(v) for k, v in overrides.items()}
    tc_overrides = dict(kv.split("=", 1) for kv in args.tc_override)
    tc_overrides = {k: _parse_value(v) for k, v in tc_overrides.items()}

    if args.list:
        for a in list_archs():
            print(a)
        return

    pairs = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    records = []
    n_ok = n_skip = n_err = 0
    for a, s, mp in pairs:
        label = f"{a} × {s} × {'2x16x16' if mp else '16x16'}"
        try:
            rec = dryrun_one(
                a, s, multi_pod=mp, verbose=not args.all, coboost_clients=args.coboost,
                overrides=overrides, tc_overrides=tc_overrides,
                mesh_shape=args.mesh_shape, kl_chunk=args.kl_chunk,
            )
        except Exception as e:  # a failure here is a bug in the system
            rec = {
                "arch": a, "shape": s, "mesh": "2x16x16" if mp else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
            }
            traceback.print_exc()
        records.append(rec)
        if rec["status"] == "ok":
            n_ok += 1
            log.info(
                "%s OK compile=%.0fs dominant=%s bound=%.4fs fits=%s",
                label, rec["compile_s"], rec["dominant"], rec["bound_s"], rec["fits_hbm"],
            )
        elif rec["status"] == "skip":
            n_skip += 1
            log.info("%s SKIP (%s)", label, rec["reason"])
        else:
            n_err += 1
            log.error("%s ERROR %s", label, rec["error"])
        if args.out:
            with open(args.out, "w") as f:
                json.dump(records, f, indent=1)
    log.info("dry-run done: %d ok, %d skip, %d error", n_ok, n_skip, n_err)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""One-shot federated learning pipeline driver — the paper end to end.

    PYTHONPATH=src python -m repro.launch.ofl --method coboosting \
        --clients 5 --alpha 0.1 --epochs 40

Builds the model market (synthetic images, Dirichlet/C_cls/lognormal
partition, SGD-m local training), then runs the chosen server-side method
and reports server / ensemble test accuracy.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from functools import partial

import jax
import numpy as np

from repro import obs
from repro.config.train import OFLConfig
from repro.core import (
    default_image_setup,
    fedavg,
    run_adi_baseline,
    run_coboosting,
    run_feddf,
    run_generator_baseline,
    uniform_weights,
)
from repro.data import make_synth_images
from repro.fed import build_market, build_market_grouped, market_eval_fn
from repro.kernels import KERNEL_BACKENDS, policy_from_flags
from repro.models.cnn import cnn_apply, init_cnn
from repro.utils import get_logger

log = get_logger("ofl")

METHODS = ("coboosting", "dense", "f_dafl", "f_adi", "feddf", "fedavg", "fedens")


def run_method(
    method: str,
    cfg: OFLConfig,
    num_classes: int,
    image_shape,
    applies,
    params,
    sizes,
    train_x,
    test_x,
    test_y,
    server_arch: str,
    seed: int,
    eval_every: int = 50,
    driver: str = "fused",
):
    """Dispatch one OFL method; returns {'server_acc':…, 'ensemble_acc':…},
    except ``fedens`` which trains no server and returns ``ensemble_acc``
    only. ``driver`` selects the fused single-dispatch epoch engine
    (default) or the legacy per-batch loop for every distillation-based
    method."""
    server_apply = partial(cnn_apply, server_arch)
    server_params = init_cnn(jax.random.key(seed + 77), server_arch, num_classes, image_shape)
    eval_fn = market_eval_fn(applies, params, server_apply, test_x, test_y)
    key = jax.random.key(seed)

    if method == "fedavg":
        avg = fedavg(params, sizes)
        return eval_fn(avg, uniform_weights(len(params)))
    if method == "fedens":
        # no server is trained here — evaluating the fresh random init would
        # record a meaningless server_acc next to the real ensemble number
        return eval_fn(None, uniform_weights(len(params)))
    if method == "feddf":
        st = run_feddf(
            applies, params, server_apply, server_params, train_x, cfg, key,
            eval_fn, eval_every, driver=driver,
        )
        return st.history[-1]
    if method == "f_adi":
        st = run_adi_baseline(
            applies, params, server_apply, server_params, image_shape, cfg, num_classes, key,
            eval_fn, eval_every, driver=driver,
        )
        return st.history[-1]
    if method in ("dense", "f_dafl"):
        gen_apply, gen_params = default_image_setup(jax.random.key(seed + 5), cfg, num_classes, image_shape)
        st = run_generator_baseline(
            method, applies, params, server_apply, server_params, gen_apply, gen_params,
            cfg, num_classes, key, eval_fn, eval_every, driver=driver,
        )
        return st.history[-1]
    # coboosting (+ ablations via component flags on cfg)
    gen_apply, gen_params = default_image_setup(jax.random.key(seed + 5), cfg, num_classes, image_shape)
    st = run_coboosting(
        applies, params, server_apply, server_params, gen_apply, gen_params,
        cfg, num_classes, key, eval_fn, eval_every, driver=driver,
    )
    return st.history[-1]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--method", default="coboosting", choices=METHODS)
    p.add_argument("--clients", type=int, default=5)
    p.add_argument("--alpha", type=float, default=0.1)
    p.add_argument("--partition", default="dirichlet", choices=("dirichlet", "c_cls", "iid"))
    p.add_argument("--c-cls", type=int, default=2)
    p.add_argument("--sigma", type=float, default=0.0, help="lognormal size skew")
    p.add_argument("--classes", type=int, default=6)
    p.add_argument("--image", type=int, default=16)
    p.add_argument("--per-class", type=int, default=150)
    p.add_argument("--epochs", type=int, default=30)
    p.add_argument("--gen-iters", type=int, default=10)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--local-epochs", type=int, default=15)
    p.add_argument("--client-archs", default="", help="comma list (heterogeneous market)")
    p.add_argument("--server-arch", default="cnn5")
    p.add_argument("--driver", default="fused", choices=("fused", "legacy"),
                   help="epoch engine: fused scan (O(1) dispatch) or legacy per-batch loop")
    p.add_argument("--no-ghs", action="store_true")
    p.add_argument("--no-dhs", action="store_true")
    p.add_argument("--no-ee", action="store_true")
    p.add_argument("--no-adv", action="store_true",
                   help="drop the adversarial generator term L_A (independent "
                        "of --no-ghs, so every Table 7 row is reachable)")
    p.add_argument("--backend", default=None, choices=KERNEL_BACKENDS,
                   help="kernel backend for every dispatched op: auto "
                        "(pallas on TPU, jnp ref elsewhere) | pallas | "
                        "pallas-interpret | ref")
    p.add_argument("--kernel-backend", default=None, choices=KERNEL_BACKENDS,
                   help="DEPRECATED: use --backend (this alias sets only the "
                        "fused-loss op)")
    p.add_argument("--ensemble-impl", default="grouped", choices=("grouped", "looped"),
                   help="client forward engine: grouped ClientBank (one vmap "
                        "per arch group) or the K-way looped baseline")
    p.add_argument("--ensemble-scan-chunk", type=int, default=0,
                   help=">0: scan over vmapped chunks of this many clients "
                        "inside each group (memory bound at large K)")
    p.add_argument("--grouped-market", action="store_true",
                   help="vmap local client training within arch groups "
                        "(build_market_grouped) instead of the per-client loop")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None)
    # telemetry (repro.obs) — off by default, zero-cost when off
    p.add_argument("--metrics-out", default=None, metavar="PATH.jsonl",
                   help="dump the ofl.* metrics registry (epoch/phase "
                        "counters + step-time histograms) as JSONL plus a "
                        ".prom Prometheus-text sibling at exit")
    p.add_argument("--trace-out", default=None, metavar="PATH.json",
                   help="record host-side phase spans and dump Chrome "
                        "trace-event JSON (Perfetto-loadable) at exit")
    p.add_argument("--profile-dir", default=None,
                   help="also run a JAX profiler trace into this directory "
                        "(the fused epoch's jax.named_scope phases show up "
                        "in the device timeline)")
    args = p.parse_args()
    obs.configure(
        metrics=bool(args.metrics_out),
        trace=bool(args.trace_out),
        profile_dir=args.profile_dir,
    )

    shape = (args.image, args.image, 3)
    cfg = OFLConfig(
        num_clients=args.clients,
        partition=args.partition,
        alpha=args.alpha,
        c_cls=args.c_cls,
        lognormal_sigma=args.sigma,
        local_epochs=args.local_epochs,
        epochs=args.epochs,
        gen_iters=args.gen_iters,
        batch_size=args.batch,
        latent_dim=32,
        buffer_batches=4,
        use_ghs=not args.no_ghs,
        use_dhs=not args.no_dhs,
        use_ee=not args.no_ee,
        use_adv=not args.no_adv,
        backend=policy_from_flags(backend=args.backend, kernel_backend=args.kernel_backend),
        ensemble_impl=args.ensemble_impl,
        ensemble_scan_chunk=args.ensemble_scan_chunk,
        seed=args.seed,
    )
    x, y = make_synth_images(args.seed, args.classes, args.per_class, shape)
    test_x, test_y = make_synth_images(args.seed + 1, args.classes, max(40, args.per_class // 4), shape)
    archs = args.client_archs.split(",") if args.client_archs else None
    if args.grouped_market:
        bank, bank_params, sizes, _ = build_market_grouped(args.seed, x, y, cfg, args.classes, archs)
        params = bank.unstack_params(bank_params)
        applies = [bank.client_apply(k) for k in range(bank.num_clients)]
    else:
        applies, params, sizes, _ = build_market(args.seed, x, y, cfg, args.classes, archs)

    result = run_method(
        args.method, cfg, args.classes, shape, applies, params, sizes,
        x, test_x, test_y, args.server_arch, args.seed, eval_every=max(args.epochs // 3, 1),
        driver=args.driver,
    )
    result = {k: v for k, v in result.items() if isinstance(v, (int, float))}
    log.info("[%s] %s", args.method, result)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"method": args.method, **result}, f, indent=1)
    if args.profile_dir:
        obs.stop_jax_profile(obs.tracer())
    if args.metrics_out:
        obs.registry().dump(args.metrics_out)
        log.info("metrics snapshot -> %s (+ .prom)", args.metrics_out)
    if args.trace_out:
        obs.tracer().dump(args.trace_out)
        log.info("trace -> %s (%d events)", args.trace_out, len(obs.tracer()))


if __name__ == "__main__":
    main()

"""End-to-end LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 300 --batch 8 --seq 256 --reduced

``--reduced`` swaps in the smoke-scale variant of the arch (this container
is a 1-CPU host); on real hardware drop it and pass ``--mesh production``.
Data is the seeded hidden-Markov token stream, so loss visibly drops below
the uniform floor within a few hundred steps.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import TrainConfig, get_arch, reduced_variant
from repro.data import make_token_stream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_lm
from repro.runtime import make_train_step
from repro.utils import get_logger, tree_size

log = get_logger("train")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--optimizer", default="adamw")
    p.add_argument("--reduced", action="store_true", help="smoke-scale variant")
    p.add_argument("--mesh", default="host", choices=("host", "production", "multipod"))
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_variant(cfg).replace(dtype="float32", param_dtype="float32")
    if cfg.family in ("audio",):
        raise SystemExit("use launch.train for LM archs; hubert trains via lm_loss on frames")

    mesh = {
        "host": make_host_mesh,
        "production": make_production_mesh,
        "multipod": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]()

    tc = TrainConfig(
        optimizer=args.optimizer,
        learning_rate=args.lr,
        schedule="linear_warmup_cosine",
        warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
        seed=args.seed,
    )
    with jax.set_mesh(mesh):
        params = init_lm(cfg, jax.random.key(args.seed))
        step_fn = make_train_step(cfg, tc)
        opt_state = step_fn.optimizer.init(params)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        log.info("arch=%s params=%.1fM mesh=%s", cfg.name, tree_size(params) / 1e6, mesh.shape)

        t0 = time.time()
        losses = []
        for i in range(args.steps):
            data = make_token_stream(args.seed * 10_000 + i, cfg.vocab_size, args.batch, args.seq)
            batch = {k: jnp.asarray(v) for k, v in data.items()}
            if cfg.family == "vlm":
                pre = cfg.num_prefix_tokens
                rng = np.random.RandomState(i)
                batch["prefix"] = jnp.asarray(
                    rng.randn(args.batch, pre, cfg.frontend_dim).astype(np.float32) * 0.02
                )
            params, opt_state, metrics = jit_step(params, opt_state, batch, jnp.asarray(i))
            losses.append(float(metrics["loss"]))
            if (i + 1) % args.log_every == 0 or i == 0:
                log.info(
                    "step %4d loss=%.4f (avg10=%.4f) %.2fs/step",
                    i,
                    losses[-1],
                    float(np.mean(losses[-10:])),
                    (time.time() - t0) / (i + 1),
                )
        log.info(
            "done: first-10 avg=%.4f last-10 avg=%.4f (uniform floor=%.4f)",
            float(np.mean(losses[:10])),
            float(np.mean(losses[-10:])),
            float(np.log(cfg.vocab_size)),
        )
        if args.ckpt_dir:
            path = save_checkpoint(args.ckpt_dir, args.steps, params, {"arch": cfg.name})
            log.info("checkpoint saved: %s", path)


if __name__ == "__main__":
    main()

"""Production mesh construction (TPU v5e).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before any jax init, and smoke
tests must keep seeing one device.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older pinned jax
    AxisType = None


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one 256-chip v5e pod) or 2×16×16 (two pods; the leading
    ``pod`` axis carries data-parallel replication across the DCN/ICI
    boundary)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """A 1×1 mesh over the single real device (tests / examples)."""
    return compat_make_mesh((1, 1), ("data", "model"))


def mesh_context(mesh):
    """``jax.set_mesh`` where the API exists (jax >= 0.5), else the Mesh's
    own context manager (jax<0.5 pins in this container) — same effect for
    the launch drivers: sharding constraints resolve against ``mesh``."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh

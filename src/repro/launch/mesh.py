"""Production mesh construction (TPU v5e).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before any jax init, and smoke
tests must keep seeing one device.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older pinned jax
    AxisType = None


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def compat_mesh(devices, axes):
    """A :class:`Mesh` over an EXPLICIT device array (submesh construction;
    ``jax.make_mesh`` always grabs every device)."""
    if AxisType is None:
        return Mesh(devices, axes)
    return Mesh(devices, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one 256-chip v5e pod) or 2×16×16 (two pods; the leading
    ``pod`` axis carries data-parallel replication across the DCN/ICI
    boundary)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """A 1×1 mesh over the single real device (tests / examples)."""
    return compat_make_mesh((1, 1), ("data", "model"))


def make_fleet_mesh(replicas: int, *, devices=None):
    """The serving-fleet mesh: ``("replica", "data", "model")`` with the
    leading axis indexing engine replicas (each replica tensor-parallels its
    engine over its ``model`` slice; ``data`` is kept for API symmetry with
    the training meshes and is 1 in serving). Replicas must divide the
    device count — a ragged fleet would strand devices silently."""
    devs = np.asarray(jax.devices() if devices is None else devices)
    n = devs.size
    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    if n % replicas:
        raise ValueError(
            f"{replicas} replicas do not divide {n} devices: every replica "
            "gets an identical mesh slice (identical compiled programs), so "
            "a ragged split would strand devices. Pick a replica count that "
            f"divides {n}."
        )
    return compat_mesh(devs.reshape(replicas, 1, n // replicas), ("replica", "data", "model"))


def replica_meshes(fleet_mesh):
    """One ``("data", "model")`` submesh per replica — what each
    :class:`~repro.serve.engine.ServeEngine` shards itself over. Submeshes
    are disjoint by construction: replica i's engine CANNOT address replica
    j's devices, which is what makes per-replica pool isolation physical."""
    devs = fleet_mesh.devices  # (replica, data, model)
    return [compat_mesh(devs[i], ("data", "model")) for i in range(devs.shape[0])]


def disagg_submeshes(mesh):
    """Split one replica's ``("data", "model")`` mesh into a
    (prefill, decode) pair of disjoint halves along the model axis — the
    compute-bound and bandwidth-bound programs each get their own devices
    and the sealed-page handoff is the only traffic between them. A
    single-device replica colocates (both halves are the same mesh): the
    disaggregated PROGRAM split still applies, only the device split
    degenerates."""
    devs = mesh.devices
    m = devs.shape[-1]
    if m < 2:
        return mesh, mesh
    half = m // 2
    prefill = compat_mesh(devs[..., :half], mesh.axis_names)
    decode = compat_mesh(devs[..., half:], mesh.axis_names)
    return prefill, decode


def mesh_context(mesh):
    """``jax.set_mesh`` where the API exists (jax >= 0.5), else the Mesh's
    own context manager (jax<0.5 pins in this container) — same effect for
    the launch drivers: sharding constraints resolve against ``mesh``."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh

"""The client ensemble A_w (Eq. 2) over possibly-heterogeneous client models.

Clients are (apply_fn, params) pairs; ``make_logits_all`` builds a single
traced function producing the (n, B, C) stack of client logits, which every
downstream component (generator loss, DHS perturbation, EE weight search,
distillation) consumes. For homogeneous clients the stacked form is a single
vmapped forward, for heterogeneous ones a python-unrolled trace — either
way one jitted program.
"""
from __future__ import annotations

from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

# The stack dtype every consumer of logits_all sees. Clients may run in
# mixed precision (a bf16 client next to f32 ones); normalizing each
# client's output here makes the (n, B, C) stack deterministic instead of
# inheriting whatever promotion jnp.stack derives from client order.
ENSEMBLE_DTYPE = jnp.float32


def uniform_weights(n: int) -> jax.Array:
    return jnp.full((n,), 1.0 / n, jnp.float32)


def data_amount_weights(sizes: Sequence[int]) -> jax.Array:
    s = jnp.asarray(sizes, jnp.float32)
    return s / jnp.sum(s)


def make_logits_all(apply_fns: List[Callable]) -> Callable:
    """Returns f(client_params_list, x) -> (n, B, C) stacked client logits."""

    def logits_all(client_params: List[Any], x: jax.Array) -> jax.Array:
        outs = [f(p, x).astype(ENSEMBLE_DTYPE) for f, p in zip(apply_fns, client_params)]
        return jnp.stack(outs, axis=0)

    return logits_all


def make_logits_all_stacked(apply_fn: Callable) -> Callable:
    """Homogeneous fast path: one vmap over a stacked param tree (clients on
    the leading axis — this is the form the distributed LM ensemble uses)."""

    def logits_all(stacked_params: Any, x: jax.Array) -> jax.Array:
        out = jax.vmap(apply_fn, in_axes=(0, None))(stacked_params, x)
        return out.astype(ENSEMBLE_DTYPE)

    return logits_all


def ensemble_logits(logits_all: jax.Array, w: jax.Array) -> jax.Array:
    """A_w(x) = Σ_k w_k f_k(x). logits_all: (n, B, C); w: (n,)."""
    return jnp.einsum("k,k...->...", w.astype(jnp.float32), logits_all.astype(jnp.float32))


def ensemble_accuracy(logits_all: jax.Array, w: jax.Array, labels: jax.Array) -> jax.Array:
    pred = jnp.argmax(ensemble_logits(logits_all, w), axis=-1)
    return jnp.mean((pred == labels).astype(jnp.float32))

"""Device-resident replay ring buffer over synthetic batches (D_S).

The legacy drivers keep D_S as a python list of device arrays and evict with
``list.pop(0)`` — every buffer access crosses the host/device boundary and
forces one dispatch per batch. Here D_S is a fixed-shape ``(capacity, B, …)``
ring that lives on device and is a pytree, so it can be carried through (and
donated to) a single jitted epoch program:

  * ``buffer_append`` writes the new batch at ``ptr`` via
    ``lax.dynamic_update_slice_in_dim`` and advances ``ptr``/``size`` —
    once full, the oldest batch is overwritten, which is exactly the
    ``append`` + ``pop(0)`` window semantics of the legacy list.
  * during warm-up (``size < capacity``) the unwritten slots hold zeros;
    consumers mask them out via ``size`` (see the fused distillation scan in
    :mod:`repro.core.epoch`).
  * logical order is oldest-first, matching list indexing:
    logical index ``i`` lives at physical slot ``(ptr - size + i) % capacity``.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp


class ReplayBuffer(NamedTuple):
    """Fixed-shape on-device ring buffer. ``x``: (capacity, B, *obs);
    ``y``: (capacity, B); ``ptr``: next write slot; ``size``: valid slots."""

    x: jax.Array
    y: jax.Array
    ptr: jax.Array
    size: jax.Array

    @property
    def capacity(self) -> int:
        return self.x.shape[0]


def buffer_init(
    capacity: int,
    batch_shape: Sequence[int],
    x_dtype=jnp.float32,
    y_dtype=jnp.int32,
) -> ReplayBuffer:
    """Preallocate a ring over ``capacity`` batches of shape ``(B, *obs)``."""
    batch_shape = tuple(batch_shape)
    return ReplayBuffer(
        x=jnp.zeros((capacity, *batch_shape), x_dtype),
        y=jnp.zeros((capacity, batch_shape[0]), y_dtype),
        ptr=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def buffer_append(buf: ReplayBuffer, x: jax.Array, y: jax.Array) -> ReplayBuffer:
    """Insert one batch, evicting the oldest once full. Traceable (the write
    position is a device scalar)."""
    cap = buf.capacity
    return ReplayBuffer(
        x=jax.lax.dynamic_update_slice_in_dim(buf.x, x[None].astype(buf.x.dtype), buf.ptr, 0),
        y=jax.lax.dynamic_update_slice_in_dim(buf.y, y[None].astype(buf.y.dtype), buf.ptr, 0),
        ptr=(buf.ptr + 1) % cap,
        size=jnp.minimum(buf.size + 1, cap),
    )


def buffer_get(buf: ReplayBuffer, slot) -> Tuple[jax.Array, jax.Array]:
    """Read one physical slot (traced index OK)."""
    return (
        jax.lax.dynamic_index_in_dim(buf.x, slot, 0, keepdims=False),
        jax.lax.dynamic_index_in_dim(buf.y, slot, 0, keepdims=False),
    )


def logical_to_slot(i, ptr, size, capacity: int):
    """Physical slot of logical (oldest-first) index ``i``. Works on ints or
    arrays; the identity the parity tests pin down."""
    return (ptr - size + i) % capacity


def buffer_as_lists(buf: ReplayBuffer) -> Tuple[List[jax.Array], List[jax.Array]]:
    """Oldest-first python lists (the legacy ``OFLState.buffer_x/y`` view).
    Host-syncs ``ptr``/``size`` — call once at end-of-run, not per epoch."""
    ptr, size = int(buf.ptr), int(buf.size)
    slots = [logical_to_slot(i, ptr, size, buf.capacity) for i in range(size)]
    return [buf.x[s] for s in slots], [buf.y[s] for s in slots]

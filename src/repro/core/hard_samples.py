"""Diverse hard-sample construction (DHS, Eq. 9–10).

One backward step through the ensemble seeks the input-space direction that
maximizes ``uᵀA_w(x)`` for a random u ~ Unif[−1,1]^C, then perturbs the
sample by ε along the L2-normalized gradient:

    x̃ = x + ε · ∇_x(uᵀA_w(x)) / ‖∇_x(uᵀA_w(x))‖₂

The randomness in u makes repeated visits to the same stored sample produce
*different* hard variants, which is why we apply it on the fly at sampling
time rather than once per epoch (equivalent under Algorithm 1, cheaper in
memory).
"""
from __future__ import annotations

from typing import Any, Callable, List

import jax
import jax.numpy as jnp

from repro.core.ensemble import ensemble_logits


def diversify(
    logits_all_fn: Callable,
    client_params: Any,
    w: jax.Array,
    x: jax.Array,
    key: jax.Array,
    epsilon: float,
) -> jax.Array:
    """Apply Eq. 10 to a batch x (B, ...). Returns x̃ of the same shape."""

    def scalar(x_in):
        la = logits_all_fn(client_params, x_in)  # (n, B, C)
        ens = ensemble_logits(la, w)  # (B, C)
        u = jax.random.uniform(key, ens.shape, jnp.float32, -1.0, 1.0)
        return jnp.sum(u * ens)

    g = jax.grad(scalar)(x)
    flat = g.reshape(g.shape[0], -1).astype(jnp.float32)
    norm = jnp.linalg.norm(flat, axis=-1)[:, None]
    direction = (flat / jnp.maximum(norm, 1e-12)).reshape(g.shape)
    return (x.astype(jnp.float32) + epsilon * direction).astype(x.dtype)

"""The paper's contribution: Co-Boosting one-shot federated distillation.

Eq. 2        -> :mod:`repro.core.ensemble`
Eq. 5-8      -> :mod:`repro.core.hardness`
Eq. 9-10     -> :mod:`repro.core.hard_samples`
Eq. 11-12    -> :mod:`repro.core.weight_search`
Algorithm 1  -> :mod:`repro.core.coboosting`
ClientBank   -> :mod:`repro.core.client_bank`
Baselines    -> :mod:`repro.core.baselines`
LM-scale     -> :mod:`repro.core.distributed`
Replay ring  -> :mod:`repro.core.buffer`
Fused epochs -> :mod:`repro.core.epoch`
"""
from repro.core.losses import ce_loss, ce_per_sample, kl_loss, kl_per_sample, entropy
from repro.core.buffer import (
    ReplayBuffer,
    buffer_init,
    buffer_append,
    buffer_get,
    buffer_as_lists,
    logical_to_slot,
)
from repro.core.epoch import (
    distill_schedule,
    make_distill_sweep,
    make_coboost_epoch,
    make_adi_epoch,
    make_feddf_epoch,
)
from repro.core.ensemble import (
    uniform_weights,
    data_amount_weights,
    make_logits_all,
    make_logits_all_stacked,
    ensemble_logits,
    ensemble_accuracy,
)
from repro.core.client_bank import ClientBank, ENSEMBLE_IMPLS, make_ensemble
from repro.core.hardness import sample_difficulty, ghs_loss, adversarial_loss, generator_loss
from repro.core.hard_samples import diversify
from repro.core.weight_search import normalize_weights, weight_loss, update_weights
from repro.core.coboosting import (
    OFLState,
    run_coboosting,
    init_synth_buffer,
    make_generator_phase,
    make_distill_step,
    make_ee_step,
    default_image_setup,
)
from repro.core.baselines import (
    fedavg,
    run_generator_baseline,
    run_adi_baseline,
    run_feddf,
)
from repro.core.distributed import (
    ensemble_lm_logits,
    client_lm_logits,
    dhs_embeds,
    ee_update_lm,
    coboost_distill_loss,
    coboost_distill_step,
)

__all__ = [
    "ce_loss",
    "ce_per_sample",
    "kl_loss",
    "kl_per_sample",
    "entropy",
    "uniform_weights",
    "data_amount_weights",
    "make_logits_all",
    "make_logits_all_stacked",
    "ensemble_logits",
    "ensemble_accuracy",
    "ClientBank",
    "ENSEMBLE_IMPLS",
    "make_ensemble",
    "sample_difficulty",
    "ghs_loss",
    "adversarial_loss",
    "generator_loss",
    "diversify",
    "normalize_weights",
    "weight_loss",
    "update_weights",
    "ReplayBuffer",
    "buffer_init",
    "buffer_append",
    "buffer_get",
    "buffer_as_lists",
    "logical_to_slot",
    "distill_schedule",
    "make_distill_sweep",
    "make_coboost_epoch",
    "make_adi_epoch",
    "make_feddf_epoch",
    "OFLState",
    "run_coboosting",
    "init_synth_buffer",
    "make_generator_phase",
    "make_distill_step",
    "make_ee_step",
    "default_image_setup",
    "fedavg",
    "run_generator_baseline",
    "run_adi_baseline",
    "run_feddf",
    "ensemble_lm_logits",
    "client_lm_logits",
    "dhs_embeds",
    "ee_update_lm",
    "coboost_distill_loss",
    "coboost_distill_step",
]

"""ClientBank: the grouped client-ensemble engine (hundreds-of-clients OFL).

``make_logits_all`` evaluates K heterogeneous clients as a python-unrolled
loop — O(K) trace cost and K serialized small forwards, which is exactly
where the Table 6 many-client regimes die. The bank instead groups clients
by (apply fn, param structure): each group's params stack into a single
leading-axis pytree and the whole group runs as ONE ``jax.vmap`` forward, so
trace cost and dispatch structure are O(#groups) = O(#architectures), not
O(K). The stacked rows concatenate in group order and a static gather
restores the original client order, so the output is the same ``(K, B, C)``
stack every consumer (generator adversarial loss, DHS perturbation, EE
weight search, fused-epoch KD) already eats — the bank is a drop-in
``logits_all_fn`` with its grouped params as the ``client_params`` pytree.

Two scale levers on top of the grouping:

* ``scan_chunk`` — a group larger than the chunk evaluates as a
  ``lax.scan`` over vmapped chunks, bounding live activations to
  (chunk, B, C) instead of (group, B, C) (the trace stays O(1) per group
  either way; this is the memory knob for hundreds of clients).
* client-axis mesh sharding — each group's stacked params (and its logits)
  are sharding-constrained along the ``clients`` logical axis
  (:mod:`repro.sharding.partition` maps it to the data mesh axes), so large
  homogeneous groups data-parallelize across the mesh with no driver
  changes.

Outputs are normalized to the ensemble dtype (f32) at this boundary —
mixed-dtype markets (a bf16 client next to f32 ones) produce a
deterministic f32 stack instead of whatever ``jnp.stack`` promotion was
implied by client order.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ensemble import ENSEMBLE_DTYPE, make_logits_all
from repro.utils.trees import tree_stack, tree_unstack


def _apply_key(fn: Callable) -> Any:
    """A hashable grouping key for an apply fn. ``functools.partial`` is
    destructured (two ``partial(cnn_apply, "mlp")`` objects must group
    together even though partial hashes by identity); anything unhashable
    falls back to object identity — worst case a singleton group, never a
    wrong group."""
    if isinstance(fn, functools.partial):
        kw = tuple(sorted(fn.keywords.items())) if fn.keywords else ()
        key = ("partial", _apply_key(fn.func), fn.args, kw)
    else:
        key = ("fn", fn)
    try:
        hash(key)
        return key
    except TypeError:
        return ("id", id(fn))


def _params_key(params: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return (treedef, tuple((tuple(l.shape), jnp.dtype(l.dtype).str) for l in leaves))


def _constrain_clients(tree: Any) -> Any:
    """Shard the leading (client) axis of a stacked group tree along the
    data mesh axes when a mesh is in context (no-op otherwise — unit tests
    and single-device runs)."""
    from repro.sharding.partition import constrain

    return jax.tree_util.tree_map(
        lambda l: constrain(l, "clients", *([None] * (l.ndim - 1))), tree
    )


@dataclasses.dataclass(frozen=True)
class ClientBank:
    """Static (host-side) description of a grouped client ensemble.

    The bank itself holds no arrays: its grouped params travel separately as
    a ``tuple`` of stacked pytrees (one per group, clients on the leading
    axis) — a plain jax pytree that threads through jitted programs exactly
    where the old per-client params tuple did. Build with
    :meth:`ClientBank.build`, evaluate with :meth:`logits_all`.
    """

    applies: Tuple[Callable, ...]  # one apply fn per group
    counts: Tuple[int, ...]  # clients per group
    order: Tuple[int, ...]  # original client index of each stacked row
    scan_chunk: int = 0
    shard_clients: bool = True

    @property
    def num_clients(self) -> int:
        return len(self.order)

    @property
    def num_groups(self) -> int:
        return len(self.applies)

    @property
    def is_client_ordered(self) -> bool:
        return self.order == tuple(range(self.num_clients))

    @classmethod
    def build(
        cls,
        apply_fns: Sequence[Callable],
        params_list: Sequence[Any],
        scan_chunk: int = 0,
        shard_clients: bool = True,
    ) -> Tuple["ClientBank", Tuple[Any, ...]]:
        """Group clients by (apply fn, param treedef + leaf shapes/dtypes)
        and stack each group. Returns ``(bank, bank_params)``; grouping
        preserves first-seen group order and within-group client order, so a
        homogeneous market is one group with ``order == range(K)``."""
        assert len(apply_fns) == len(params_list), (len(apply_fns), len(params_list))
        groups: Dict[Any, int] = {}
        applies: List[Callable] = []
        members: List[List[int]] = []
        for k, (fn, p) in enumerate(zip(apply_fns, params_list)):
            key = (_apply_key(fn), _params_key(p))
            g = groups.get(key)
            if g is None:
                g = groups[key] = len(applies)
                applies.append(fn)
                members.append([])
            members[g].append(k)
        bank = cls(
            applies=tuple(applies),
            counts=tuple(len(m) for m in members),
            order=tuple(k for m in members for k in m),
            scan_chunk=int(scan_chunk),
            shard_clients=shard_clients,
        )
        bank_params = tuple(
            tree_stack([params_list[k] for k in m]) for m in members
        )
        return bank, bank_params

    # -- forward ------------------------------------------------------------

    def _group_logits(self, g: int, stacked: Any, x: jax.Array) -> jax.Array:
        """One group's (n_g, B, C) client logits: a single vmapped forward,
        or a scan over vmapped chunks when the group outgrows scan_chunk."""
        apply_fn, n = self.applies[g], self.counts[g]
        if self.shard_clients:
            stacked = _constrain_clients(stacked)
        fwd = jax.vmap(apply_fn, in_axes=(0, None))
        c = self.scan_chunk
        if c <= 0 or n <= c:
            out = fwd(stacked, x)
        else:
            pad = (-n) % c
            if pad:
                stacked = jax.tree_util.tree_map(
                    lambda l: jnp.concatenate([l, l[:pad]], axis=0), stacked
                )
            chunked = jax.tree_util.tree_map(
                lambda l: l.reshape((n + pad) // c, c, *l.shape[1:]), stacked
            )
            _, outs = jax.lax.scan(
                lambda _, ch: (None, fwd(ch, x)), None, chunked
            )
            out = outs.reshape(-1, *outs.shape[2:])[:n]
        out = out.astype(ENSEMBLE_DTYPE)
        if self.shard_clients:
            out = _constrain_clients(out)
        return out

    def logits_all(self, bank_params: Tuple[Any, ...], x: jax.Array) -> jax.Array:
        """f(bank_params, x) -> (K, B, C) stacked client logits in ORIGINAL
        client order — the drop-in replacement for the fn built by
        :func:`repro.core.ensemble.make_logits_all`."""
        outs = [self._group_logits(g, sp, x) for g, sp in enumerate(bank_params)]
        stacked = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
        if self.is_client_ordered:
            return stacked
        inv = np.argsort(np.asarray(self.order))
        return jnp.take(stacked, jnp.asarray(inv), axis=0)

    # -- interop ------------------------------------------------------------

    def unstack_params(self, bank_params: Tuple[Any, ...]) -> List[Any]:
        """Back to the per-client params list, in original client order."""
        rows = []
        for n, sp in zip(self.counts, bank_params):
            rows.extend(tree_unstack(sp, n))
        out: List[Any] = [None] * self.num_clients
        for row, k in zip(rows, self.order):
            out[k] = row
        return out

    def stack_params(self, params_list: Sequence[Any]) -> Tuple[Any, ...]:
        """Regroup a client-ordered params list into this bank's layout."""
        assert len(params_list) == self.num_clients
        out, at = [], 0
        for n in self.counts:
            out.append(tree_stack([params_list[k] for k in self.order[at : at + n]]))
            at += n
        return tuple(out)

    def client_apply(self, k: int) -> Callable:
        """The apply fn of original client ``k``."""
        at = 0
        for g, n in enumerate(self.counts):
            if k in self.order[at : at + n]:
                return self.applies[g]
            at += n
        raise IndexError(k)


ENSEMBLE_IMPLS = ("grouped", "looped")


def make_ensemble(
    apply_fns: Sequence[Callable],
    params_list: Sequence[Any],
    impl: str = "grouped",
    scan_chunk: int = 0,
    shard_clients: bool = True,
) -> Tuple[Callable, Any]:
    """The one ensemble-construction entry every method driver uses.

    Returns ``(logits_all_fn, ensemble_params)`` where
    ``logits_all_fn(ensemble_params, x) -> (K, B, C)`` in client order:

    * ``impl="grouped"`` — a :class:`ClientBank` (params stacked per arch
      group, vmapped group forwards; the production path);
    * ``impl="looped"``  — the original python-unrolled per-client loop over
      a tuple of param trees (the parity baseline and the legacy driver's
      path).
    """
    if impl == "looped":
        return make_logits_all(list(apply_fns)), tuple(params_list)
    if impl != "grouped":
        raise ValueError(f"unknown ensemble impl {impl!r}; expected one of {ENSEMBLE_IMPLS}")
    bank, bank_params = ClientBank.build(
        apply_fns, params_list, scan_chunk=scan_chunk, shard_clients=shard_clients
    )
    return bank.logits_all, bank_params

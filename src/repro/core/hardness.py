"""GHM sample difficulty (Eq. 5) and the hard-sample-enhanced generator loss
(Eq. 6–8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.losses import ce_per_sample, kl_per_sample


def sample_difficulty(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """d(x, f) = 1 − σ(f(x))_y  (Eq. 5). logits: (B, C); labels: (B,)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    py = jnp.take_along_axis(probs, labels[:, None], axis=-1)[:, 0]
    return 1.0 - py


def ghs_loss(ens_logits: jax.Array, labels: jax.Array, use_ghs: bool = True) -> jax.Array:
    """L_H (Eq. 6): difficulty-weighted CE. With ``use_ghs=False`` this is the
    plain CE of Eq. 3 (the ablation's base row). The difficulty weight is
    treated as a constant (stop-gradient), matching GHM usage."""
    ce = ce_per_sample(ens_logits, labels)
    if not use_ghs:
        return jnp.mean(ce)
    d = jax.lax.stop_gradient(sample_difficulty(ens_logits, labels))
    return jnp.mean(d * ce)


def adversarial_loss(ens_logits: jax.Array, server_logits: jax.Array, temperature: float = 1.0) -> jax.Array:
    """L_A (Eq. 7): −KL(A_w(x) || f_S(x)) — the generator *maximizes* the
    ensemble/server disagreement."""
    return -jnp.mean(kl_per_sample(ens_logits, server_logits, temperature))


def generator_loss(
    ens_logits: jax.Array,
    server_logits: jax.Array,
    labels: jax.Array,
    *,
    beta: float = 1.0,
    use_ghs: bool = True,
    use_adv: bool = True,
    kl_temperature: float = 1.0,
) -> jax.Array:
    """L(θ_G) = L_H + β·L_A (Eq. 8)."""
    loss = ghs_loss(ens_logits, labels, use_ghs)
    if use_adv:
        loss = loss + beta * adversarial_loss(ens_logits, server_logits, kl_temperature)
    return loss

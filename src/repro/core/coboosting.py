"""Co-Boosting (Algorithm 1) — the paper's primary contribution.

Each global epoch:
  1. *Data boosting* — ``T_G`` generator steps on Eq. 8 (difficulty-weighted
     CE against the current ensemble + adversarial server disagreement),
     then the fresh batch joins the synthetic buffer D_S.
  2. *DHS* — samples drawn from D_S are diversified on the fly by the
     one-step input perturbation of Eq. 10.
  3. *Ensemble boosting (EE)* — one sign-gradient step (Eq. 12) on the
     ensembling weights w over the hard samples.
  4. *Distillation* — SGD-momentum steps on the temperature-KL between the
     re-weighted ensemble and the server (Eq. 4).

Component toggles (``use_ghs`` / ``use_dhs`` / ``use_ee`` / ``use_adv``)
reproduce the Table 7 ablation; with all off the loop degenerates to the
DENSE-style base pipeline (CE-only generator, uniform ensemble).

Two epoch drivers share this module's loss machinery:

  * ``driver="fused"`` (default) — the whole epoch is one jitted program
    over the device-resident ring buffer (:mod:`repro.core.epoch`): O(1)
    dispatches per epoch, losses synced only at eval boundaries. Its Eq. 4 /
    Eq. 6 losses follow ``cfg.backend_for("loss")`` (the fused differentiable
    Pallas kernels on TPU, the jnp composition elsewhere).
  * ``driver="legacy"`` — DEPRECATED alias scheduled for removal: the
    original python loop, one jitted program per stage and per replay batch
    (it never routes through the Pallas kernels). The parity contract has
    moved onto ``backend="ref"`` vs ``backend="pallas-interpret"`` of the
    fused driver (tests/grad_harness.py), so the legacy loop is no longer
    the oracle — selecting it emits a :class:`DeprecationWarning`.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.config.train import OFLConfig, TrainConfig
from repro.core.buffer import ReplayBuffer, buffer_as_lists, buffer_init
from repro.core.client_bank import make_ensemble
from repro.core.ensemble import ensemble_logits, uniform_weights
from repro.core.epoch import _sample_zy, distill_schedule, make_coboost_epoch
from repro.core.hard_samples import diversify
from repro.core.hardness import generator_loss
from repro.core.losses import kl_loss
from repro.core.weight_search import update_weights
from repro.models.generator import image_generator, init_image_generator
from repro.optim import adam, constant_schedule, sgdm
from repro.optim.optimizers import apply_updates
from repro.utils import get_logger

log = get_logger("coboosting")


def _warn_legacy_driver() -> None:
    """``driver="legacy"`` is a deprecated alias scheduled for removal.

    The per-batch python loop stopped being the parity oracle when the
    kernel contract moved to ``backend="ref"`` vs ``backend="pallas*"`` of
    the fused driver (both passes — see tests/grad_harness.py); it survives
    only as a dispatch-overhead benchmark baseline."""
    warnings.warn(
        "driver='legacy' is deprecated and scheduled for removal: use the "
        "fused driver (default) with backend='ref' for a pure-jnp oracle run",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclasses.dataclass
class OFLState:
    """Mutable python-side state of the OFL run."""

    server_params: Any
    gen_params: Any
    weights: jax.Array
    buffer_x: List[jax.Array]
    buffer_y: List[jax.Array]
    history: List[Dict[str, float]]
    buffer: Optional[ReplayBuffer] = None
    dispatch_count: int = 0  # fused-driver epoch_step calls (O(1)/epoch)


def init_synth_buffer(gen_apply: Callable, gen_params: Any, cfg: OFLConfig) -> ReplayBuffer:
    """Preallocate the ring from the generator's output spec (no forward)."""
    z = jax.ShapeDtypeStruct((cfg.batch_size, cfg.latent_dim), jnp.float32)
    y = jax.ShapeDtypeStruct((cfg.batch_size,), jnp.int32)
    xs = jax.eval_shape(gen_apply, gen_params, z, y)
    return buffer_init(cfg.buffer_batches, xs.shape, xs.dtype)


def make_generator_phase(
    logits_all_fn: Callable,
    server_apply: Callable,
    gen_apply: Callable,
    cfg: OFLConfig,
):
    """One jitted program running the T_G generator updates of Algorithm 1
    lines 5–9 (Adam on Eq. 8)."""
    opt = adam(constant_schedule(cfg.gen_lr))

    def loss_fn(gen_params, z, y, client_params, w, server_params):
        x = gen_apply(gen_params, z, y)
        la = logits_all_fn(client_params, x)
        ens = ensemble_logits(la, w)
        s_logits = server_apply(server_params, x)
        return generator_loss(
            ens,
            s_logits,
            y,
            beta=cfg.beta,
            use_ghs=cfg.use_ghs,
            use_adv=cfg.use_adv,
            kl_temperature=cfg.gen_kl_temperature,
        )

    @jax.jit
    def phase(gen_params, opt_state, z, y, client_params, w, server_params):
        def body(i, carry):
            gp, st = carry
            loss, grads = jax.value_and_grad(loss_fn)(gp, z, y, client_params, w, server_params)
            updates, st = opt.update(grads, st, gp, i)
            gp = apply_updates(gp, updates)
            return gp, st

        gen_params, opt_state = jax.lax.fori_loop(0, cfg.gen_iters, body, (gen_params, opt_state))
        final_loss = loss_fn(gen_params, z, y, client_params, w, server_params)
        return gen_params, opt_state, final_loss

    return phase, opt


def make_distill_step(
    logits_all_fn: Callable,
    server_apply: Callable,
    cfg: OFLConfig,
):
    """One jitted server distillation step (Eq. 4) with optional on-the-fly
    DHS diversification (Eq. 10)."""
    opt = sgdm(constant_schedule(cfg.server_lr), momentum=0.9)

    def loss_fn(server_params, x, client_params, w):
        la = logits_all_fn(client_params, x)
        ens = ensemble_logits(la, w)
        s_logits = server_apply(server_params, x)
        return kl_loss(ens, s_logits, cfg.kd_temperature)

    @jax.jit
    def step(server_params, opt_state, x, key, client_params, w, step_idx):
        if cfg.use_dhs:
            x = diversify(logits_all_fn, client_params, w, x, key, cfg.epsilon)
        loss, grads = jax.value_and_grad(loss_fn)(server_params, x, client_params, w)
        updates, opt_state = opt.update(grads, opt_state, server_params, step_idx)
        server_params = apply_updates(server_params, updates)
        return server_params, opt_state, loss

    return step, opt


def make_ee_step(logits_all_fn: Callable, cfg: OFLConfig, num_clients: int):
    """One jitted Eq. 12 sign step on the ensembling weights (on hard
    samples)."""
    mu = cfg.mu / num_clients

    @jax.jit
    def step(w, x, y, key, client_params):
        if cfg.use_dhs:
            x = diversify(logits_all_fn, client_params, w, x, key, cfg.epsilon)
        la = logits_all_fn(client_params, x)
        return update_weights(w, la, y, mu)

    return step


def run_coboosting(
    client_applies: List[Callable],
    client_params: List[Any],
    server_apply: Callable,
    server_params: Any,
    gen_apply: Callable,
    gen_params: Any,
    cfg: OFLConfig,
    num_classes: int,
    key: jax.Array,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 50,
    init_weights: Optional[jax.Array] = None,
    driver: str = "fused",
) -> OFLState:
    """Algorithm 1. ``eval_fn(server_params, w) -> dict`` is called every
    ``eval_every`` epochs for history logging. ``driver`` selects the fused
    single-dispatch epoch program (whose distillation/generator losses run
    the ``cfg.backend_for("loss")`` kernel path) or the legacy per-batch python
    loop (always pure jnp — the parity baseline).

    NOTE: on accelerator backends the fused driver donates the caller's
    ``server_params`` / ``gen_params`` (and derived state) to the epoch
    program — they are invalidated after the first epoch; copy them first if
    you need them again (e.g. for a legacy A/B run from the same init)."""
    n = len(client_applies)
    # fused driver: client forwards run through the grouped ClientBank
    # (cfg.ensemble_impl, O(#groups) trace) — the legacy driver always uses
    # the python-unrolled per-client loop, keeping it the parity baseline.
    impl = cfg.ensemble_impl if driver == "fused" else "looped"
    logits_all_fn, client_params = make_ensemble(
        client_applies, client_params, impl=impl, scan_chunk=cfg.ensemble_scan_chunk
    )
    w = uniform_weights(n) if init_weights is None else init_weights

    if driver == "fused":
        epoch_step, gen_opt, srv_opt = make_coboost_epoch(
            logits_all_fn, server_apply, gen_apply, cfg, n, num_classes
        )
        gen_opt_state = gen_opt.init(gen_params)
        srv_opt_state = srv_opt.init(server_params)
        buf = init_synth_buffer(gen_apply, gen_params, cfg)
        state = OFLState(server_params, gen_params, w, [], [], [])
        srv_steps = jnp.zeros((), jnp.int32)
        for epoch in range(cfg.epochs):
            slot_order, n_valid = distill_schedule(epoch, cfg.buffer_batches)
            # the span/timer bracket the DISPATCH of the fused program — no
            # sync is forced, so in steady state dispatch time backpressures
            # to epoch time once the device pipeline fills. Per-phase device
            # time comes from jax.named_scope inside the program (visible
            # under --profile-dir), not from host stamps.
            t0 = time.perf_counter()
            with obs.span("ofl.epoch", epoch=epoch, driver="fused"):
                (
                    state.server_params, srv_opt_state, state.gen_params, gen_opt_state,
                    state.weights, buf, key, srv_steps, gloss, dmean,
                ) = epoch_step(
                    state.server_params, srv_opt_state, state.gen_params, gen_opt_state,
                    state.weights, buf, key, srv_steps, slot_order, n_valid, client_params,
                )
            state.dispatch_count += 1
            obs.observe("ofl.epoch.step_s", time.perf_counter() - t0, driver="fused")
            obs.inc("ofl.epoch.count")
            obs.inc("ofl.epoch.dispatches")
            obs.inc("ofl.gen.steps", cfg.gen_iters)
            if cfg.use_ee:
                obs.inc("ofl.ee.steps")
            obs.inc("ofl.kd.steps", int(n_valid))
            if eval_fn is not None and ((epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1):
                metrics = eval_fn(state.server_params, state.weights)
                metrics.update(epoch=epoch, gen_loss=float(gloss), distill_loss=float(dmean))
                state.history.append(metrics)
                log.info(
                    "epoch %d gen=%.4f distill=%.4f %s",
                    epoch, float(gloss), float(dmean),
                    {k: round(v, 4) for k, v in metrics.items() if isinstance(v, float)},
                )
        state.buffer = buf
        state.buffer_x, state.buffer_y = buffer_as_lists(buf)
        return state
    if driver != "legacy":
        raise ValueError(f"unknown driver {driver!r}")
    _warn_legacy_driver()

    gen_phase, gen_opt = make_generator_phase(logits_all_fn, server_apply, gen_apply, cfg)
    distill_step, srv_opt = make_distill_step(logits_all_fn, server_apply, cfg)
    ee_step = make_ee_step(logits_all_fn, cfg, n)

    gen_opt_state = gen_opt.init(gen_params)
    srv_opt_state = srv_opt.init(server_params)

    state = OFLState(server_params, gen_params, w, [], [], [])
    srv_step_idx = 0
    for epoch in range(cfg.epochs):
        t_ep = time.perf_counter()
        key, k1, k2, k3 = jax.random.split(key, 4)
        # 1. generator phase (lines 5–9)
        z, y = _sample_zy(k1, cfg.batch_size, cfg.latent_dim, num_classes)
        t0 = time.perf_counter()
        with obs.span("ofl.gen.boost", epoch=epoch, iters=cfg.gen_iters):
            state.gen_params, gen_opt_state, gloss = gen_phase(
                state.gen_params, gen_opt_state, z, y, client_params, state.weights, state.server_params
            )
        obs.observe("ofl.gen.step_s", time.perf_counter() - t0)
        obs.inc("ofl.gen.steps", cfg.gen_iters)
        obs.inc("ofl.epoch.dispatches")
        x_new = gen_apply(state.gen_params, z, y)
        state.buffer_x.append(x_new)
        state.buffer_y.append(y)
        if len(state.buffer_x) > cfg.buffer_batches:
            state.buffer_x.pop(0)
            state.buffer_y.pop(0)

        # 2–3. EE on the (diversified) fresh hard batch (lines 11–14)
        if cfg.use_ee:
            t0 = time.perf_counter()
            with obs.span("ofl.ee.weight_search", epoch=epoch):
                state.weights = ee_step(state.weights, x_new, y, k2, client_params)
            obs.observe("ofl.ee.step_s", time.perf_counter() - t0)
            obs.inc("ofl.ee.steps")
            obs.inc("ofl.epoch.dispatches")

        # 4. server distillation over the replay buffer (lines 16–18)
        dlosses = []
        with obs.span("ofl.kd", epoch=epoch, batches=len(state.buffer_x)):
            for bi in np.random.RandomState(epoch).permutation(len(state.buffer_x)):
                k3, kb = jax.random.split(k3)
                t0 = time.perf_counter()
                state.server_params, srv_opt_state, dl = distill_step(
                    state.server_params,
                    srv_opt_state,
                    state.buffer_x[bi],
                    kb,
                    client_params,
                    state.weights,
                    jnp.asarray(srv_step_idx, jnp.int32),
                )
                obs.observe("ofl.kd.step_s", time.perf_counter() - t0)
                obs.inc("ofl.kd.steps")
                obs.inc("ofl.epoch.dispatches")
                srv_step_idx += 1
                dlosses.append(dl)  # device scalar — no per-batch host sync
        obs.observe("ofl.epoch.step_s", time.perf_counter() - t_ep, driver="legacy")
        obs.inc("ofl.epoch.count")

        if eval_fn is not None and ((epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1):
            dmean = float(np.mean(jax.device_get(dlosses)))
            metrics = eval_fn(state.server_params, state.weights)
            metrics.update(epoch=epoch, gen_loss=float(gloss), distill_loss=dmean)
            state.history.append(metrics)
            log.info(
                "epoch %d gen=%.4f distill=%.4f %s",
                epoch,
                float(gloss),
                dmean,
                {k: round(v, 4) for k, v in metrics.items() if isinstance(v, float)},
            )
    return state


def default_image_setup(key, cfg: OFLConfig, num_classes: int, image_shape: Tuple[int, int, int]):
    """Convenience: init the paper's DCGAN-style generator + its apply fn."""
    gen_params = init_image_generator(key, cfg.latent_dim, num_classes, image_shape)
    gen_apply = lambda p, z, y: image_generator(p, z, y, image_shape)
    return gen_apply, gen_params

"""Distillation losses (Eq. 1, 4 of the paper) with temperature scaling."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ce_per_sample(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-sample cross entropy. logits: (B, C); labels: (B,) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - ll


def ce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean(ce_per_sample(logits, labels))


def kl_per_sample(teacher_logits: jax.Array, student_logits: jax.Array, temperature: float = 1.0) -> jax.Array:
    """KL(softmax(t/T) || softmax(s/T)) · T² per sample. Shapes (B, C) (or
    (..., C) — reduced over the last axis only)."""
    t = teacher_logits.astype(jnp.float32) / temperature
    s = student_logits.astype(jnp.float32) / temperature
    pt = jax.nn.log_softmax(t, axis=-1)
    ps = jax.nn.log_softmax(s, axis=-1)
    kl = jnp.sum(jnp.exp(pt) * (pt - ps), axis=-1)
    return kl * (temperature**2)


def kl_loss(teacher_logits: jax.Array, student_logits: jax.Array, temperature: float = 1.0) -> jax.Array:
    return jnp.mean(kl_per_sample(teacher_logits, student_logits, temperature))


def entropy(logits: jax.Array) -> jax.Array:
    """Mean predictive entropy (used by the F-DAFL baseline's info loss)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.sum(jnp.exp(lp) * lp, axis=-1))

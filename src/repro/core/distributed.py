"""Distributed (LM-scale) Co-Boosting — the paper's technique promoted to a
first-class feature of the multi-pod framework.

The paper runs Algorithm 1 over small CNNs. Here the clients are instances
of the assigned LM architectures: client params are *stacked* along a
leading K axis (they shard exactly like ordinary params — FSDP over `data`,
tensor over `model` — because the sharding rules pad leading dims with
``None``), and the ensemble forward is a ``lax.scan`` over clients
accumulating weighted logits. One SPMD program, no per-client dispatch.

Token models have no pixel space, so (DESIGN.md §5/§6):
  * the generator synthesizes *embedding-space* sequences (B, S, d);
  * DHS (Eq. 10) perturbs those embeddings;
  * the EE labels y_s are target-token ids scored at the final position.

Everything here is shape-polymorphic and jit/pjit-friendly — the multi-pod
dry-run lowers :func:`coboost_distill_step` for the MoE/hybrid archs.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ensemble import ensemble_logits
from repro.core.losses import kl_loss, kl_per_sample
from repro.core.weight_search import normalize_weights
from repro.models.transformer import lm_forward
from repro.sharding import constrain
from repro.utils import tree_index


def ensemble_lm_logits(stacked_params: Any, cfg, batch: Dict, w: jax.Array) -> jax.Array:
    """Weighted ensemble logits A_w (Eq. 2) over K stacked LM clients.

    Scans over the client axis so activations for only one client are live
    at a time (K× params, 1× activations)."""

    def body(acc, inp):
        w_k, p_k = inp
        logits, _ = lm_forward(p_k, cfg, batch)
        return acc + w_k * logits.astype(jnp.float32), None

    k = w.shape[0]
    sample = jax.eval_shape(lambda p: lm_forward(tree_index(p, 0), cfg, batch)[0], stacked_params)
    acc0 = jnp.zeros(sample.shape, jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (w.astype(jnp.float32), stacked_params))
    return acc


def client_lm_logits(stacked_params: Any, cfg, batch: Dict) -> jax.Array:
    """Per-client final-position logits (K, B, V) — the EE weight search
    operand. Only the last position is kept to bound memory."""

    def body(_, p_k):
        logits, _ = lm_forward(p_k, cfg, batch)
        return None, logits[:, -1].astype(jnp.float32)

    _, out = jax.lax.scan(body, None, stacked_params)
    return out


def dhs_embeds(
    stacked_params: Any, cfg, batch: Dict, w: jax.Array, key: jax.Array, epsilon: float
) -> Dict:
    """Eq. 10 in embedding space: perturb batch["embeds"] along the gradient
    of uᵀA_w at the final position."""
    embeds = batch["embeds"]

    def scalar(e):
        b = dict(batch, embeds=e)
        ens = ensemble_lm_logits(stacked_params, cfg, b, w)[:, -1]  # (B, V)
        u = jax.random.uniform(key, ens.shape, jnp.float32, -1.0, 1.0)
        return jnp.sum(u * ens)

    g = jax.grad(scalar)(embeds)
    flat = g.reshape(g.shape[0], -1).astype(jnp.float32)
    norm = jnp.maximum(jnp.linalg.norm(flat, axis=-1), 1e-12)[:, None]
    direction = (flat / norm).reshape(g.shape)
    new = (embeds.astype(jnp.float32) + epsilon * direction).astype(embeds.dtype)
    return dict(batch, embeds=new)


def ee_update_lm(
    w: jax.Array,
    stacked_params: Any,
    cfg,
    batch: Dict,
    labels: jax.Array,
    mu: float,
) -> jax.Array:
    """Eq. 12 on LM clients: sign step on w against final-position CE."""
    la = client_lm_logits(stacked_params, cfg, batch)  # (K, B, V)

    def loss(w_):
        ens = ensemble_logits(la, w_)  # (B, V)
        logits = ens.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - ll)

    g = jax.grad(loss)(w)
    return normalize_weights(w - mu * jnp.sign(g))


def coboost_distill_loss(
    server_params: Any,
    stacked_client_params: Any,
    w: jax.Array,
    cfg,
    batch: Dict,
    temperature: float = 4.0,
    kl_chunk: int = 0,
) -> jax.Array:
    """Eq. 4 at LM scale: temperature-KL between the weighted client
    ensemble and the server over every position.

    ``kl_chunk > 0`` enables the §Perf memory lever: the LM heads are
    factored out of the client/server forwards (``lm_features``), and the
    (B, S, V) teacher/student logits are produced one sequence-chunk at a
    time — the live vocab-sized tensors shrink from O(S·V) to O(chunk·V)
    while the stored per-client features are only O(K·S·d)."""
    if kl_chunk <= 0:
        teacher = jax.lax.stop_gradient(ensemble_lm_logits(stacked_client_params, cfg, batch, w))
        student, aux = lm_forward(server_params, cfg, batch)
        loss = kl_loss(teacher, student, temperature)
        return loss + cfg.router_aux_coef * aux

    from repro.models.transformer import head_matrix, lm_features

    def feats_of(p):
        f, _ = lm_features(p, cfg, batch)
        return f.astype(jnp.bfloat16)

    def body(_, p_k):
        return None, (feats_of(p_k), head_matrix(p_k, cfg).astype(jnp.bfloat16))

    _, (cfeats, cheads) = jax.lax.scan(body, None, stacked_client_params)  # (K,B,S,d),(K,d,V)
    cfeats = jax.lax.stop_gradient(cfeats)
    cheads = jax.lax.stop_gradient(cheads)
    sfeat, aux = lm_features(server_params, cfg, batch)
    shead = head_matrix(server_params, cfg)

    b, s, d = sfeat.shape
    chunk = min(kl_chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    wf = w.astype(jnp.float32)

    def chunk_body(acc, idx):
        sl = jax.lax.dynamic_slice_in_dim(sfeat, idx * chunk, chunk, axis=1)
        cl = jax.lax.dynamic_slice_in_dim(cfeats, idx * chunk, chunk, axis=2)
        t = jnp.einsum("k,kbcd,kdv->bcv", wf, cl.astype(jnp.float32), cheads.astype(jnp.float32))
        st = jnp.einsum("bcd,dv->bcv", sl, shead.astype(sl.dtype))
        kl = kl_per_sample(t, st, temperature)  # (B, chunk)
        return acc + jnp.sum(kl), None

    total, _ = jax.lax.scan(chunk_body, jnp.zeros((), jnp.float32), jnp.arange(nc))
    loss = total / (b * s)
    return loss + cfg.router_aux_coef * aux


def coboost_distill_step(
    server_params: Any,
    opt_state: Any,
    stacked_client_params: Any,
    w: jax.Array,
    cfg,
    batch: Dict,
    opt,
    step: jax.Array,
    temperature: float = 4.0,
    epsilon: float = 0.0,
    key: Optional[jax.Array] = None,
):
    """One server distillation step (with optional in-step DHS). This is the
    function the multi-pod dry-run lowers for the paper-technique shapes."""
    if epsilon > 0.0 and key is not None and "embeds" in batch:
        batch = dhs_embeds(stacked_client_params, cfg, batch, w, key, epsilon)
    loss, grads = jax.value_and_grad(coboost_distill_loss)(
        server_params, stacked_client_params, w, cfg, batch, temperature
    )
    updates, opt_state = opt.update(grads, opt_state, server_params, step)
    from repro.optim.optimizers import apply_updates

    server_params = apply_updates(server_params, updates)
    return server_params, opt_state, loss

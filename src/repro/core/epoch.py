"""Fused one-epoch OFL programs: O(1) dispatches per global epoch.

The legacy drivers (``run_coboosting`` and the shared loops in
:mod:`repro.core.baselines` — both deprecated aliases now) dispatch one
jitted ``distill_step`` per replay batch and ``float()`` the scalar loss
each iteration — O(buffer) dispatches plus O(buffer) host syncs per epoch. Here the whole epoch (generator phase →
buffer append → EE step → distillation sweep) is ONE jitted program per
method: the synthetic buffer is the device-resident ring of
:mod:`repro.core.buffer` and the distillation sweep is a ``lax.scan`` over
physical buffer slots, with masked validity while the ring is warming up.
Losses accumulate on device; the host converts them only at eval boundaries.

Parity contract with the legacy loops (pinned by tests/test_buffer_epoch.py):

  * identical PRNG split structure — the per-epoch key splits and the
    per-batch ``k3, kb = split(k3)`` chain happen in the same order, so the
    same stream drives generator noise, DHS directions and labels;
  * identical batch visit order — the host replays the legacy
    ``np.random.RandomState(epoch).permutation(len(buffer))`` and maps
    logical indices to ring slots (:func:`distill_schedule`); padding slots
    are appended AFTER the valid ones so the split chain stays aligned;
  * identical optimizer-step indexing — the server step counter advances
    only on valid (unmasked) scan iterations.

Server/optimizer/buffer state is donated back to the program on accelerator
backends (donation is a no-op on CPU, so we skip it there to avoid warnings).

The Eq. 4 / Eq. 6 losses inside these programs route through the
differentiable fused Pallas kernels (:mod:`repro.kernels`) according to
``cfg.backend_for("loss")`` — the backend covers BOTH passes: every
``jax.grad`` these epoch programs take through ``ensemble_kl`` / ``ghm_ce``
runs the fused Pallas backward kernels under "pallas"/"pallas-interpret",
and plain autodiff of the jnp oracle under "ref". "auto" runs the compiled
kernels on TPU and the pure-jnp composition elsewhere (see
:mod:`repro.kernels.dispatch`), so the CPU parity contract with the legacy
loops below is preserved bit-for-bit; the end-to-end grad contract is
ref-vs-interpret parity per method (tests/grad_harness.py).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.train import OFLConfig
from repro.core.buffer import ReplayBuffer, buffer_append, buffer_get
from repro.core.ensemble import ensemble_logits
from repro.core.hard_samples import diversify
from repro.core.hardness import generator_loss
from repro.core.losses import kl_loss
from repro.core.weight_search import update_weights
from repro.kernels import ensemble_kl, ghm_ce
from repro.kernels.dispatch import resolve
from repro.optim import adam, constant_schedule, sgdm
from repro.optim.optimizers import apply_updates


def distill_schedule(epoch: int, capacity: int) -> Tuple[jax.Array, jax.Array]:
    """Host-side replica of the legacy per-epoch sweep schedule.

    After epoch ``epoch``'s append the ring holds ``min(epoch+1, capacity)``
    batches and ``ptr == (epoch+1) % capacity``; the legacy loop visits
    logical indices in ``np.random.RandomState(epoch).permutation(size)``
    order. Returns a fixed-shape ``(capacity,)`` slot order (valid slots
    first, zero padding after) plus the valid count — fixed shapes mean no
    recompilation across the warm-up epochs.
    """
    size = min(epoch + 1, capacity)
    ptr = (epoch + 1) % capacity
    perm = np.random.RandomState(epoch).permutation(size)
    order = np.zeros((capacity,), np.int32)
    order[:size] = (ptr - size + perm) % capacity
    return jnp.asarray(order), jnp.asarray(size, jnp.int32)


def _jit_epoch(fn: Callable, donate: Tuple[int, ...]):
    """jit with state donation where the backend supports it (not CPU)."""
    if jax.default_backend() == "cpu":
        return jax.jit(fn)
    return jax.jit(fn, donate_argnums=donate)


def _masked_update(valid, old, new):
    return jax.tree_util.tree_map(lambda a, b: jnp.where(valid, b, a), old, new)


def make_kd_loss(
    logits_all_fn: Callable,
    server_apply: Callable,
    temperature: float,
    kernel_backend: str = "auto",
):
    """Eq. 4: temperature-KL between the re-weighted ensemble and the server.

    ``kernel_backend`` (resolved once, at make time) routes the loss through
    the differentiable fused :func:`repro.kernels.ensemble_kl` kernel — the
    Pallas paths never materialize A_w in the forward pass — or through the
    legacy jnp composition (``"ref"``; the auto choice off-TPU)."""
    backend = resolve("loss", kernel_backend)

    if backend == "ref":

        def loss_fn(server_params, x, client_params, w):
            ens = ensemble_logits(logits_all_fn(client_params, x), w)
            return kl_loss(ens, server_apply(server_params, x), temperature)

    else:

        def loss_fn(server_params, x, client_params, w):
            la = logits_all_fn(client_params, x)
            s_logits = server_apply(server_params, x)
            return jnp.mean(ensemble_kl(la, s_logits, w, temperature=temperature, backend=backend))

    return loss_fn


def make_distill_sweep(
    logits_all_fn: Callable,
    server_apply: Callable,
    srv_opt,
    cfg: OFLConfig,
    use_dhs: bool,
):
    """The fused replacement for the per-batch ``distill_step`` loop: one
    ``lax.scan`` over ring slots, masked while the buffer warms up."""
    loss_fn = make_kd_loss(logits_all_fn, server_apply, cfg.kd_temperature, cfg.backend_for("loss"))

    def sweep(server_params, srv_opt_state, buf, k3, w, client_params, slot_order, n_valid, srv_step0):
        def body(carry, xs):
            sp, st, k, step, dsum, dcnt = carry
            slot, pos = xs
            k, kb = jax.random.split(k)
            x, _ = buffer_get(buf, slot)
            if use_dhs:
                x = diversify(logits_all_fn, client_params, w, x, kb, cfg.epsilon)
            loss, grads = jax.value_and_grad(loss_fn)(sp, x, client_params, w)
            updates, st2 = srv_opt.update(grads, st, sp, step)
            sp2 = apply_updates(sp, updates)
            valid = pos < n_valid
            sp = _masked_update(valid, sp, sp2)
            st = _masked_update(valid, st, st2)
            dsum = dsum + jnp.where(valid, loss, 0.0)
            dcnt = dcnt + valid.astype(jnp.int32)
            step = step + valid.astype(jnp.int32)
            return (sp, st, k, step, dsum, dcnt), None

        cap = buf.capacity
        init = (
            server_params,
            srv_opt_state,
            k3,
            jnp.asarray(srv_step0, jnp.int32),
            jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.int32),
        )
        (sp, st, _, step, dsum, dcnt), _ = jax.lax.scan(
            body, init, (slot_order, jnp.arange(cap, dtype=jnp.int32))
        )
        return sp, st, step, dsum / jnp.maximum(dcnt, 1).astype(jnp.float32)

    return sweep


def _sample_zy(key, batch: int, latent: int, num_classes: int):
    kz, ky = jax.random.split(key)
    z = jax.random.normal(kz, (batch, latent))
    y = jax.random.randint(ky, (batch,), 0, num_classes)
    return z, y


def make_coboost_epoch(
    logits_all_fn: Callable,
    server_apply: Callable,
    gen_apply: Callable,
    cfg: OFLConfig,
    num_clients: int,
    num_classes: int,
    gen_objective: Optional[Callable] = None,
    use_ee: Optional[bool] = None,
    distill_dhs: Optional[bool] = None,
):
    """One fused Algorithm-1 epoch. With ``gen_objective`` set (a
    ``f(ens, y, x) -> loss``) and ``use_ee=False`` this is also the DENSE /
    F-DAFL epoch — the contrast the paper draws is exactly which generator
    objective runs and whether the ensemble weights move.

    Returns ``(epoch_step, gen_opt, srv_opt)``; ``epoch_step`` maps

        (server_params, srv_opt_state, gen_params, gen_opt_state, w, buf,
         key, srv_step0, slot_order, n_valid, client_params)
        -> (server_params, srv_opt_state, gen_params, gen_opt_state, w, buf,
            key', srv_steps, gloss, dmean)
    """
    gen_opt = adam(constant_schedule(cfg.gen_lr))
    srv_opt = sgdm(constant_schedule(cfg.server_lr), momentum=0.9)
    use_ee = cfg.use_ee if use_ee is None else use_ee
    distill_dhs = cfg.use_dhs if distill_dhs is None else distill_dhs
    mu = cfg.mu / num_clients
    # legacy run_coboosting splits 4 keys per epoch, the generator baselines 3;
    # any EE variant needs the 4th key so k2 never aliases the distill chain
    nsplit = 4 if (gen_objective is None or use_ee) else 3

    backend = resolve("loss", cfg.backend_for("loss"))

    def gen_loss_fn(gp, z, y, client_params, w, server_params):
        x = gen_apply(gp, z, y)
        la = logits_all_fn(client_params, x)
        if gen_objective is not None:
            return gen_objective(ensemble_logits(la, w), y, x)
        if backend == "ref":
            s_logits = server_apply(server_params, x)
            return generator_loss(
                ensemble_logits(la, w),
                s_logits,
                y,
                beta=cfg.beta,
                use_ghs=cfg.use_ghs,
                use_adv=cfg.use_adv,
                kl_temperature=cfg.gen_kl_temperature,
            )
        # kernel path for Eq. 8: L_H via the fused GHM-CE (difficulty is
        # stop-gradiented, matching ghs_loss) + β·L_A via the fused KL, both
        # without materializing A_w in the forward pass
        loss = jnp.mean(
            ghm_ce(la, y, w, weighted=cfg.use_ghs, backend=backend, stop_difficulty_grad=True)
        )
        if cfg.use_adv:
            s_logits = server_apply(server_params, x)
            loss = loss - cfg.beta * jnp.mean(
                ensemble_kl(la, s_logits, w, temperature=cfg.gen_kl_temperature, backend=backend)
            )
        return loss

    sweep = make_distill_sweep(logits_all_fn, server_apply, srv_opt, cfg, distill_dhs)

    def epoch_step(
        server_params, srv_opt_state, gen_params, gen_opt_state, w, buf,
        key, srv_step0, slot_order, n_valid, client_params,
    ):
        keys = jax.random.split(key, nsplit)
        key, k1, k3 = keys[0], keys[1], keys[-1]

        # jax.named_scope annotates the XLA ops of each Algorithm-1 phase —
        # zero host cost, but an --profile-dir device trace shows the phases
        # as named regions lining up with the host-side ofl.epoch span.
        # 1. generator phase (Algorithm 1 lines 5-9)
        with jax.named_scope("ofl.gen.boost"):
            z, y = _sample_zy(k1, cfg.batch_size, cfg.latent_dim, num_classes)

            def gbody(i, carry):
                gp, st = carry
                _, grads = jax.value_and_grad(gen_loss_fn)(gp, z, y, client_params, w, server_params)
                updates, st = gen_opt.update(grads, st, gp, i)
                return apply_updates(gp, updates), st

            gen_params, gen_opt_state = jax.lax.fori_loop(
                0, cfg.gen_iters, gbody, (gen_params, gen_opt_state)
            )
            gloss = gen_loss_fn(gen_params, z, y, client_params, w, server_params)
            x_new = gen_apply(gen_params, z, y)
            buf = buffer_append(buf, x_new, y)

        # 2-3. EE on the (diversified) fresh hard batch (lines 11-14). The
        # Eq. 11/12 CE-over-ensemble + w-cotangent runs inside the fused
        # ghm_ce(weighted=False) kernel on the Pallas backends.
        if use_ee:
            with jax.named_scope("ofl.ee.weight_search"):
                k2 = keys[2]
                xe = diversify(logits_all_fn, client_params, w, x_new, k2, cfg.epsilon) if cfg.use_dhs else x_new
                w = update_weights(w, logits_all_fn(client_params, xe), y, mu, backend=backend)

        # 4. server distillation over the replay ring (lines 16-18)
        with jax.named_scope("ofl.kd"):
            server_params, srv_opt_state, srv_steps, dmean = sweep(
                server_params, srv_opt_state, buf, k3, w, client_params, slot_order, n_valid, srv_step0
            )
        return (
            server_params, srv_opt_state, gen_params, gen_opt_state, w, buf,
            key, srv_steps, gloss, dmean,
        )

    return _jit_epoch(epoch_step, donate=(0, 1, 2, 3, 4, 5)), gen_opt, srv_opt


def make_adi_epoch(
    logits_all_fn: Callable,
    server_apply: Callable,
    image_shape: Tuple[int, int, int],
    cfg: OFLConfig,
    num_classes: int,
    inv_loss: Callable,
):
    """F-ADI fused epoch: direct pixel-batch optimization instead of a
    generator, then the same append + distillation sweep (no DHS)."""
    synth_opt = adam(constant_schedule(0.05))
    srv_opt = sgdm(constant_schedule(cfg.server_lr), momentum=0.9)
    sweep = make_distill_sweep(logits_all_fn, server_apply, srv_opt, cfg, use_dhs=False)

    def epoch_step(server_params, srv_opt_state, w, buf, key, srv_step0, slot_order, n_valid, client_params):
        key, k1, k2, k3 = jax.random.split(key, 4)
        y = jax.random.randint(k1, (cfg.batch_size,), 0, num_classes)
        x0 = jax.random.normal(k2, (cfg.batch_size, *image_shape)) * 0.5
        st0 = synth_opt.init(x0)

        def body(i, carry):
            x, st = carry
            _, g = jax.value_and_grad(inv_loss)(x, y, client_params)
            updates, st = synth_opt.update(g, st, x, i)
            return apply_updates(x, updates), st

        x, _ = jax.lax.fori_loop(0, cfg.gen_iters, body, (x0, st0))
        x = jnp.clip(x, -1.0, 1.0)
        buf = buffer_append(buf, x, y)
        server_params, srv_opt_state, srv_steps, dmean = sweep(
            server_params, srv_opt_state, buf, k3, w, client_params, slot_order, n_valid, srv_step0
        )
        return server_params, srv_opt_state, buf, key, srv_steps, dmean

    return _jit_epoch(epoch_step, donate=(0, 1, 3)), srv_opt


def make_feddf_epoch(logits_all_fn: Callable, server_apply: Callable, cfg: OFLConfig):
    """FedDF fused epoch: one scan over the (pre-stacked, fixed-size) real
    validation batches in a host-supplied permutation — no buffer, no mask."""
    srv_opt = sgdm(constant_schedule(cfg.server_lr), momentum=0.9)
    loss_fn = make_kd_loss(logits_all_fn, server_apply, cfg.kd_temperature, cfg.backend_for("loss"))

    def epoch_step(server_params, srv_opt_state, key, srv_step0, order, val_batches, w, client_params):
        key, k3 = jax.random.split(key)

        def body(carry, bi):
            sp, st, k, step = carry
            k, kb = jax.random.split(k)
            xb = jax.lax.dynamic_index_in_dim(val_batches, bi, 0, keepdims=False)
            loss, grads = jax.value_and_grad(loss_fn)(sp, xb, client_params, w)
            updates, st = srv_opt.update(grads, st, sp, step)
            return (apply_updates(sp, updates), st, k, step + 1), loss

        init = (server_params, srv_opt_state, k3, jnp.asarray(srv_step0, jnp.int32))
        (sp, st, _, step), losses = jax.lax.scan(body, init, order)
        return sp, st, key, step, jnp.mean(losses)

    return _jit_epoch(epoch_step, donate=(0, 1)), srv_opt

"""Ensemble-enhancement weight search (EE, Eq. 11–12).

One sign-gradient step on the ensembling weights per synthetic batch:

    w ← Normalize(w − μ · sign(∇_w L_w(w)))

where L_w is the CE of the weighted ensemble on the (hard) synthetic batch
and Normalize clips to [0, 1] and renormalizes to the simplex.

The CE-over-ensemble and its ``w`` gradient route through the fused
:func:`repro.kernels.ghm_ce` kernel with ``weighted=False`` (plain CE): on
the Pallas backends A_w is never materialized in the forward pass and the
kernel's ``custom_vjp`` supplies the ``w`` cotangent directly, so the whole
Eq. 11/12 step is fused. ``backend="ref"`` (the default, and what the legacy
parity loop uses) is the pure-jnp oracle under plain autodiff — numerically
the original ``ensemble_logits`` + ``ce_per_sample`` composition.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels import ghm_ce


def normalize_weights(w: jax.Array) -> jax.Array:
    w = jnp.clip(w, 0.0, 1.0)
    return w / jnp.maximum(jnp.sum(w), 1e-12)


def weight_loss(
    w: jax.Array, logits_all: jax.Array, labels: jax.Array, backend: str = "ref"
) -> jax.Array:
    """L_w (Eq. 11) on precomputed client logits (n, B, C)."""
    return jnp.mean(ghm_ce(logits_all, labels, w, weighted=False, backend=backend))


def update_weights(
    w: jax.Array, logits_all: jax.Array, labels: jax.Array, mu: float, backend: str = "ref"
) -> jax.Array:
    """One Eq. 12 step. ``mu`` is the paper's step size (0.1/n by default)."""
    g = jax.grad(lambda w_: weight_loss(w_, logits_all, labels, backend))(w)
    return normalize_weights(w - mu * jnp.sign(g))

"""Ensemble-enhancement weight search (EE, Eq. 11–12).

One sign-gradient step on the ensembling weights per synthetic batch:

    w ← Normalize(w − μ · sign(∇_w L_w(w)))

where L_w is the CE of the weighted ensemble on the (hard) synthetic batch
and Normalize clips to [0, 1] and renormalizes to the simplex.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.ensemble import ensemble_logits
from repro.core.losses import ce_per_sample


def normalize_weights(w: jax.Array) -> jax.Array:
    w = jnp.clip(w, 0.0, 1.0)
    return w / jnp.maximum(jnp.sum(w), 1e-12)


def weight_loss(w: jax.Array, logits_all: jax.Array, labels: jax.Array) -> jax.Array:
    """L_w (Eq. 11) on precomputed client logits (n, B, C)."""
    ens = ensemble_logits(logits_all, w)
    return jnp.mean(ce_per_sample(ens, labels))


def update_weights(
    w: jax.Array, logits_all: jax.Array, labels: jax.Array, mu: float
) -> jax.Array:
    """One Eq. 12 step. ``mu`` is the paper's step size (0.1/n by default)."""
    g = jax.grad(weight_loss)(w, logits_all, labels)
    return normalize_weights(w - mu * jnp.sign(g))

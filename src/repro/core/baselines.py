"""One-shot FL baselines the paper compares against (Table 1).

* FedAvg  — parameter averaging (homogeneous archs only).
* FedENS  — the uniform-weight logit ensemble, no distillation.
* FedDF   — ensemble distillation on an available (validation) dataset.
* F-DAFL  — data-free KD: generator trained with CE + information-entropy
            (the DAFL losses), uniform ensemble, then distill.
* F-ADI   — data-free KD: DeepInversion-style direct noise optimization
            with CE + TV/L2 image priors, uniform ensemble, then distill.
* DENSE   — generator trained with CE + a batch-diversity term, uniform
            ensemble, then distill.

All reuse the distillation machinery of :mod:`repro.core.coboosting`; the
only differences are the synthesis objective and the fixed uniform weights,
which is exactly the contrast the paper draws (no co-boosting of data and
ensemble). Under ``driver="fused"`` every distillation sweep here (DENSE,
F-DAFL, F-ADI, FedDF) runs the Eq. 4 loss through the ``cfg.backend_for("loss")``
kernel path of :func:`repro.core.epoch.make_kd_loss` — forward AND backward
(the kernels carry fused Pallas VJPs; ``backend="ref"`` is the pure-jnp
oracle). ``driver="legacy"`` is a deprecated alias scheduled for removal.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.train import OFLConfig
from repro.core.buffer import buffer_as_lists, buffer_init
from repro.core.client_bank import make_ensemble
from repro.core.coboosting import (
    OFLState,
    _sample_zy,
    _warn_legacy_driver,
    init_synth_buffer,
    make_distill_step,
)
from repro.core.epoch import distill_schedule, make_adi_epoch, make_coboost_epoch, make_feddf_epoch
from repro.core.ensemble import ensemble_logits, uniform_weights
from repro.core.losses import ce_loss, ce_per_sample, entropy, kl_loss
from repro.optim import adam, constant_schedule
from repro.optim.optimizers import apply_updates
from repro.utils import get_logger, tree_stack

log = get_logger("baselines")


# ---------------------------------------------------------------------------
# FedAvg


def fedavg(client_params: List[Any], sizes: Optional[Sequence[int]] = None) -> Any:
    """Data-amount-weighted parameter average (homogeneous archs only)."""
    n = len(client_params)
    ws = np.full((n,), 1.0 / n) if sizes is None else np.asarray(sizes, np.float64) / np.sum(sizes)
    stacked = tree_stack(client_params)
    w = jnp.asarray(ws, jnp.float32)

    def avg(leaf):
        return jnp.tensordot(w, leaf.astype(jnp.float32), axes=1).astype(leaf.dtype)

    return jax.tree_util.tree_map(avg, stacked)


# ---------------------------------------------------------------------------
# generator objectives for the data-free baselines


def _dafl_loss(ens, y, x):
    """DAFL: one-hot CE + information entropy (encourage class balance)."""
    return ce_loss(ens, y) - 5.0 * entropy(jnp.mean(ens, axis=0, keepdims=True))


def _dense_loss(ens, y, x):
    """DENSE: CE + batch diversity (push samples apart in pixel space)."""
    b = x.shape[0]
    flat = x.reshape(b, -1)
    d2 = jnp.sum(jnp.square(flat[:, None] - flat[None, :]), axis=-1)
    div = -jnp.mean(d2) / flat.shape[-1]
    return ce_loss(ens, y) + 0.1 * div


def _tv_l2(x):
    tv = jnp.mean(jnp.abs(x[:, 1:] - x[:, :-1])) + jnp.mean(jnp.abs(x[:, :, 1:] - x[:, :, :-1]))
    return tv + 1e-3 * jnp.mean(jnp.square(x))


GEN_OBJECTIVES: Dict[str, Callable] = {
    "f_dafl": _dafl_loss,
    "dense": _dense_loss,
}


def run_generator_baseline(
    method: str,
    client_applies: List[Callable],
    client_params: List[Any],
    server_apply: Callable,
    server_params: Any,
    gen_apply: Callable,
    gen_params: Any,
    cfg: OFLConfig,
    num_classes: int,
    key: jax.Array,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 50,
    driver: str = "fused",
) -> OFLState:
    """F-DAFL / DENSE: two-stage synth→distill with a fixed uniform ensemble.
    On accelerator backends the fused driver donates the caller's server/gen
    params — invalidated after epoch 0; copy first if reused."""
    objective = GEN_OBJECTIVES[method]
    n = len(client_applies)
    impl = cfg.ensemble_impl if driver == "fused" else "looped"
    logits_all_fn, client_params = make_ensemble(
        client_applies, client_params, impl=impl, scan_chunk=cfg.ensemble_scan_chunk
    )
    w = uniform_weights(n)

    if driver == "fused":
        epoch_step, gen_opt, srv_opt = make_coboost_epoch(
            logits_all_fn, server_apply, gen_apply, cfg, n, num_classes,
            gen_objective=objective, use_ee=False, distill_dhs=False,
        )
        gen_opt_state = gen_opt.init(gen_params)
        srv_opt_state = srv_opt.init(server_params)
        buf = init_synth_buffer(gen_apply, gen_params, cfg)
        state = OFLState(server_params, gen_params, w, [], [], [])
        srv_steps = jnp.zeros((), jnp.int32)
        for epoch in range(cfg.epochs):
            slot_order, n_valid = distill_schedule(epoch, cfg.buffer_batches)
            (
                state.server_params, srv_opt_state, state.gen_params, gen_opt_state,
                w, buf, key, srv_steps, gloss, dmean,
            ) = epoch_step(
                state.server_params, srv_opt_state, state.gen_params, gen_opt_state,
                w, buf, key, srv_steps, slot_order, n_valid, client_params,
            )
            state.weights = w
            state.dispatch_count += 1
            if eval_fn is not None and ((epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1):
                metrics = eval_fn(state.server_params, w)
                metrics.update(epoch=epoch, gen_loss=float(gloss), distill_loss=float(dmean))
                state.history.append(metrics)
                log.info("[%s] epoch %d %s", method, epoch, {k: round(v, 4) for k, v in metrics.items() if isinstance(v, float)})
        state.buffer = buf
        state.buffer_x, state.buffer_y = buffer_as_lists(buf)
        return state
    if driver != "legacy":
        raise ValueError(f"unknown driver {driver!r}")
    _warn_legacy_driver()

    gen_opt = adam(constant_schedule(cfg.gen_lr))

    def gen_loss_fn(gp, z, y, cp):
        x = gen_apply(gp, z, y)
        ens = ensemble_logits(logits_all_fn(cp, x), w)
        return objective(ens, y, x)

    @jax.jit
    def gen_phase(gp, opt_state, z, y, cp):
        def body(i, carry):
            gp, st = carry
            loss, grads = jax.value_and_grad(gen_loss_fn)(gp, z, y, cp)
            updates, st = gen_opt.update(grads, st, gp, i)
            return apply_updates(gp, updates), st

        gp, opt_state = jax.lax.fori_loop(0, cfg.gen_iters, body, (gp, opt_state))
        return gp, opt_state, gen_loss_fn(gp, z, y, cp)

    no_dhs_cfg = dataclasses.replace(cfg, use_dhs=False)
    distill_step, srv_opt = make_distill_step(logits_all_fn, server_apply, no_dhs_cfg)

    gen_opt_state = gen_opt.init(gen_params)
    srv_opt_state = srv_opt.init(server_params)
    state = OFLState(server_params, gen_params, w, [], [], [])
    step_idx = 0
    for epoch in range(cfg.epochs):
        key, k1, k3 = jax.random.split(key, 3)
        z, y = _sample_zy(k1, cfg.batch_size, cfg.latent_dim, num_classes)
        state.gen_params, gen_opt_state, gloss = gen_phase(
            state.gen_params, gen_opt_state, z, y, client_params
        )
        state.buffer_x.append(gen_apply(state.gen_params, z, y))
        state.buffer_y.append(y)
        if len(state.buffer_x) > cfg.buffer_batches:
            state.buffer_x.pop(0)
            state.buffer_y.pop(0)
        dlosses = []
        for bi in np.random.RandomState(epoch).permutation(len(state.buffer_x)):
            k3, kb = jax.random.split(k3)
            state.server_params, srv_opt_state, dl = distill_step(
                state.server_params,
                srv_opt_state,
                state.buffer_x[bi],
                kb,
                client_params,
                w,
                jnp.asarray(step_idx, jnp.int32),
            )
            step_idx += 1
            dlosses.append(dl)  # device scalar — no per-batch host sync
        if eval_fn is not None and ((epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1):
            metrics = eval_fn(state.server_params, w)
            metrics.update(
                epoch=epoch, gen_loss=float(gloss),
                distill_loss=float(np.mean(jax.device_get(dlosses))),
            )
            state.history.append(metrics)
            log.info("[%s] epoch %d %s", method, epoch, {k: round(v, 4) for k, v in metrics.items() if isinstance(v, float)})
    return state


def run_adi_baseline(
    client_applies: List[Callable],
    client_params: List[Any],
    server_apply: Callable,
    server_params: Any,
    image_shape: Tuple[int, int, int],
    cfg: OFLConfig,
    num_classes: int,
    key: jax.Array,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 50,
    driver: str = "fused",
) -> OFLState:
    """F-ADI: optimize pixel batches directly (DeepInversion without BN
    statistics — our clients are GroupNorm, so only image priors apply)."""
    n = len(client_applies)
    impl = cfg.ensemble_impl if driver == "fused" else "looped"
    logits_all_fn, client_params = make_ensemble(
        client_applies, client_params, impl=impl, scan_chunk=cfg.ensemble_scan_chunk
    )
    w = uniform_weights(n)
    opt = adam(constant_schedule(0.05))

    def inv_loss(x, y, cp):
        ens = ensemble_logits(logits_all_fn(cp, x), w)
        return ce_loss(ens, y) + 2.5e-2 * _tv_l2(x)

    if driver == "fused":
        epoch_step, srv_opt = make_adi_epoch(
            logits_all_fn, server_apply, image_shape, cfg, num_classes, inv_loss
        )
        srv_opt_state = srv_opt.init(server_params)
        buf = buffer_init(cfg.buffer_batches, (cfg.batch_size, *image_shape))
        state = OFLState(server_params, None, w, [], [], [])
        srv_steps = jnp.zeros((), jnp.int32)
        for epoch in range(cfg.epochs):
            slot_order, n_valid = distill_schedule(epoch, cfg.buffer_batches)
            state.server_params, srv_opt_state, buf, key, srv_steps, _ = epoch_step(
                state.server_params, srv_opt_state, w, buf, key, srv_steps,
                slot_order, n_valid, client_params,
            )
            state.dispatch_count += 1
            if eval_fn is not None and ((epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1):
                metrics = eval_fn(state.server_params, w)
                metrics["epoch"] = epoch
                state.history.append(metrics)
                log.info("[f_adi] epoch %d %s", epoch, {k: round(v, 4) for k, v in metrics.items() if isinstance(v, float)})
        state.buffer = buf
        state.buffer_x, state.buffer_y = buffer_as_lists(buf)
        return state
    if driver != "legacy":
        raise ValueError(f"unknown driver {driver!r}")
    _warn_legacy_driver()

    @jax.jit
    def synth_phase(x, y, cp):
        st = opt.init(x)

        def body(i, carry):
            x, st = carry
            loss, g = jax.value_and_grad(inv_loss)(x, y, cp)
            updates, st = opt.update(g, st, x, i)
            return apply_updates(x, updates), st

        x, _ = jax.lax.fori_loop(0, cfg.gen_iters, body, (x, st))
        return jnp.clip(x, -1.0, 1.0)

    distill_step, srv_opt = make_distill_step(
        logits_all_fn, server_apply, dataclasses.replace(cfg, use_dhs=False)
    )
    srv_opt_state = srv_opt.init(server_params)
    state = OFLState(server_params, None, w, [], [], [])
    step_idx = 0
    for epoch in range(cfg.epochs):
        key, k1, k2, k3 = jax.random.split(key, 4)
        y = jax.random.randint(k1, (cfg.batch_size,), 0, num_classes)
        x0 = jax.random.normal(k2, (cfg.batch_size, *image_shape)) * 0.5
        x = synth_phase(x0, y, client_params)
        state.buffer_x.append(x)
        state.buffer_y.append(y)
        if len(state.buffer_x) > cfg.buffer_batches:
            state.buffer_x.pop(0)
            state.buffer_y.pop(0)
        for bi in np.random.RandomState(epoch).permutation(len(state.buffer_x)):
            k3, kb = jax.random.split(k3)
            state.server_params, srv_opt_state, dl = distill_step(
                state.server_params, srv_opt_state, state.buffer_x[bi], kb, client_params, w,
                jnp.asarray(step_idx, jnp.int32),
            )
            step_idx += 1
        if eval_fn is not None and ((epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1):
            metrics = eval_fn(state.server_params, w)
            metrics["epoch"] = epoch
            state.history.append(metrics)
            log.info("[f_adi] epoch %d %s", epoch, {k: round(v, 4) for k, v in metrics.items() if isinstance(v, float)})
    return state


def run_feddf(
    client_applies: List[Callable],
    client_params: List[Any],
    server_apply: Callable,
    server_params: Any,
    val_x: jax.Array,
    cfg: OFLConfig,
    key: jax.Array,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 50,
    driver: str = "fused",
) -> OFLState:
    """FedDF: distill the uniform ensemble on real validation data (the
    paper marks this baseline as impractical — it needs data)."""
    n = len(client_applies)
    impl = cfg.ensemble_impl if driver == "fused" else "looped"
    logits_all_fn, client_params = make_ensemble(
        client_applies, client_params, impl=impl, scan_chunk=cfg.ensemble_scan_chunk
    )
    w = uniform_weights(n)
    nb = val_x.shape[0] // cfg.batch_size

    if driver == "fused":
        epoch_step, srv_opt = make_feddf_epoch(logits_all_fn, server_apply, cfg)
        srv_opt_state = srv_opt.init(server_params)
        val_batches = val_x[: nb * cfg.batch_size].reshape(nb, cfg.batch_size, *val_x.shape[1:])
        state = OFLState(server_params, None, w, [], [], [])
        srv_steps = jnp.zeros((), jnp.int32)
        for epoch in range(cfg.epochs):
            order = jnp.asarray(np.random.RandomState(epoch).permutation(nb).astype(np.int32))
            state.server_params, srv_opt_state, key, srv_steps, _ = epoch_step(
                state.server_params, srv_opt_state, key, srv_steps, order, val_batches, w, client_params
            )
            state.dispatch_count += 1
            if eval_fn is not None and ((epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1):
                metrics = eval_fn(state.server_params, w)
                metrics["epoch"] = epoch
                state.history.append(metrics)
                log.info("[feddf] epoch %d %s", epoch, {k: round(v, 4) for k, v in metrics.items() if isinstance(v, float)})
        return state
    if driver != "legacy":
        raise ValueError(f"unknown driver {driver!r}")
    _warn_legacy_driver()

    distill_step, srv_opt = make_distill_step(
        logits_all_fn, server_apply, dataclasses.replace(cfg, use_dhs=False)
    )
    srv_opt_state = srv_opt.init(server_params)
    state = OFLState(server_params, None, w, [], [], [])
    step_idx = 0
    for epoch in range(cfg.epochs):
        key, k3 = jax.random.split(key)
        order = np.random.RandomState(epoch).permutation(nb)
        for bi in order:
            k3, kb = jax.random.split(k3)
            xb = val_x[bi * cfg.batch_size : (bi + 1) * cfg.batch_size]
            state.server_params, srv_opt_state, dl = distill_step(
                state.server_params, srv_opt_state, xb, kb, client_params, w,
                jnp.asarray(step_idx, jnp.int32),
            )
            step_idx += 1
        if eval_fn is not None and ((epoch + 1) % eval_every == 0 or epoch == cfg.epochs - 1):
            metrics = eval_fn(state.server_params, w)
            metrics["epoch"] = epoch
            state.history.append(metrics)
            log.info("[feddf] epoch %d %s", epoch, {k: round(v, 4) for k, v in metrics.items() if isinstance(v, float)})
    return state

"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504
(masked-prediction codebook), encoder-only (same trunk as wav2vec2)
[arXiv:2106.07447]. The conv feature extractor is a stub: ``input_specs``
provides 512-dim frame features (DESIGN.md modality carve-out); no decode
shapes (encoder-only)."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="hubert-xlarge",
        family="audio",
        source="arXiv:2106.07447",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        act="gelu",
        frontend="audio",
        frontend_dim=512,
        num_prefix_tokens=1,
    )
)

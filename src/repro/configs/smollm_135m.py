"""smollm-135m [dense] — 30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152, llama-arch small, tied embeddings
[hf:HuggingFaceTB/SmolLM-135M]."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="smollm-135m",
        family="dense",
        source="hf:HuggingFaceTB/SmolLM-135M",
        num_layers=30,
        d_model=576,
        num_heads=9,
        num_kv_heads=3,
        head_dim=64,
        d_ff=1536,
        vocab_size=49152,
        tie_embeddings=True,
    )
)

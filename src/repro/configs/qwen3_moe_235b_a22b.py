"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) expert
d_ff=1536 vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment sheet)",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        moe_d_ff=1536,
        vocab_size=151936,
        num_experts=128,
        experts_per_token=8,
        qk_norm=True,
        rope_theta=1_000_000.0,
        moe_group_size=2048,
        moe_capacity_factor=1.25,
    )
)

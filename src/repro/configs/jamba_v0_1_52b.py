"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; Mamba:attention 7:1 interleave (1 attn per 8 layers), MoE 16
experts top-2 on every other layer [arXiv:2403.19887]."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        source="arXiv:2403.19887",
        ssm_kind="mamba",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        moe_d_ff=14336,
        vocab_size=65536,
        num_experts=16,
        experts_per_token=2,
        attn_every=8,
        moe_every=2,
        ssm_state_dim=16,
        ssm_conv_dim=4,
        ssm_expand=2,
        ssm_chunk=256,
        moe_group_size=4096,
    )
)

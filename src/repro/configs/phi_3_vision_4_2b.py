"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H d_ff=8192 vocab=32064;
phi3-mini text decoder + CLIP ViT-L/14-336 vision frontend
[hf:microsoft/Phi-3-vision-128k-instruct]. The ViT is a stub:
``input_specs`` provides 576 precomputed 1024-dim patch embeddings
(24×24 grid) which the in-model projector maps to d_model."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        source="hf:microsoft/Phi-3-vision-128k-instruct",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        frontend="vision",
        frontend_dim=1024,
        num_prefix_tokens=576,
        rope_theta=10000.0,
    )
)

"""xlstm-125m [ssm] — 12L d_model=768 4 heads vocab=50304; mLSTM blocks
with one sLSTM block per 4 (the paper's mixed [m:s] stacking)
[arXiv:2405.04517]. d_ff=0: xLSTM blocks carry their own projections."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        source="arXiv:2405.04517",
        ssm_kind="xlstm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        slstm_every=4,
        xlstm_heads=4,
        ssm_expand=2,
        tie_embeddings=True,
    )
)

"""Assigned-architecture configs. Importing this package registers every
architecture with :mod:`repro.config.registry` (``--arch <id>`` in the
launchers)."""
from repro.configs import (
    qwen3_moe_235b_a22b,
    mixtral_8x7b,
    xlstm_125m,
    hubert_xlarge,
    smollm_135m,
    phi_3_vision_4_2b,
    qwen3_32b,
    granite_3_2b,
    internlm2_20b,
    jamba_v0_1_52b,
)

ASSIGNED_ARCHS = [
    "qwen3-moe-235b-a22b",
    "mixtral-8x7b",
    "xlstm-125m",
    "hubert-xlarge",
    "smollm-135m",
    "phi-3-vision-4.2b",
    "qwen3-32b",
    "granite-3-2b",
    "internlm2-20b",
    "jamba-v0.1-52b",
]

"""Table 7 — component ablation: GHS (hard-sample generator loss), DHS
(on-the-fly diverse hard samples), EE (ensemble reweighting). The all-off
row is the DENSE-style base pipeline; the paper's claim is each component
helps and all three together is best."""
from __future__ import annotations

from benchmarks.common import SCALE, bench_setting, get_scale, print_csv

COMBOS_QUICK = [
    (False, False, False),
    (True, False, False),
    (False, False, True),
    (True, True, True),
]
COMBOS_FULL = [
    (a, b, c) for a in (False, True) for b in (False, True) for c in (False, True)
]


def main() -> list:
    sc = get_scale()
    combos = COMBOS_FULL if SCALE == "full" else COMBOS_QUICK
    rows = []
    for ghs, dhs, ee in combos:
        for seed in sc.seeds:
            res = bench_setting(
                ("coboosting",), sc, seed=seed, alpha=0.1,
                use_ghs=ghs, use_dhs=dhs, use_ee=ee, use_adv=ghs,
            )
            r = res["coboosting"]
            rows.append(dict(GHS=int(ghs), DHS=int(dhs), EE=int(ee), seed=seed,
                             server_acc=round(r["server_acc"], 4),
                             ensemble_acc=round(r["ensemble_acc"], 4)))
    print_csv("table7_ablation (GHS/DHS/EE components)", rows)
    return rows


if __name__ == "__main__":
    main()

"""Table 6/11 — varying client count. Expected: Co-Boosting's edge over
DENSE grows with n (weight search matters more with more clients)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import SCALE, bench_setting, get_scale, print_csv


def main(ns=None) -> list:
    sc = get_scale()
    ns = ns or ((5, 10, 20) if SCALE == "full" else (3, 5))
    methods = ("dense", "coboosting")
    rows = []
    for n in ns:
        sc_n = dataclasses.replace(sc, clients=n)
        for seed in sc.seeds:
            res = bench_setting(methods, sc_n, seed=seed, num_clients=n)
            for m, r in res.items():
                rows.append(dict(clients=n, seed=seed, method=m,
                                 server_acc=round(r["server_acc"], 4),
                                 ensemble_acc=round(r["ensemble_acc"], 4)))
    print_csv("table6_clients (client-count sweep)", rows)
    return rows


if __name__ == "__main__":
    main()

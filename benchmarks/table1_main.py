"""Table 1 — server accuracy of all methods across Dirichlet heterogeneity
levels (paper: 5 datasets × α ∈ {0.05, 0.1, 0.3} × 6 methods). Scaled:
SynthDigits, α sweep, all methods. Expected ordering (paper's claim):
Co-Boosting > DENSE/F-DAFL/F-ADI ≥ FedDF >> FedAvg."""
from __future__ import annotations

from benchmarks.common import SCALE, bench_setting, get_scale, print_csv

METHODS = ("fedavg", "feddf", "f_adi", "f_dafl", "dense", "coboosting")


def main(alphas=None, methods=METHODS) -> list:
    sc = get_scale()
    alphas = alphas or ((0.05, 0.1, 0.3) if SCALE == "full" else (0.1,))
    rows = []
    for alpha in alphas:
        for seed in sc.seeds:
            res = bench_setting(methods, sc, seed=seed, alpha=alpha)
            for m, r in res.items():
                rows.append(
                    dict(alpha=alpha, seed=seed, method=m,
                         server_acc=round(r["server_acc"], 4),
                         ensemble_acc=round(r["ensemble_acc"], 4),
                         seconds=r["seconds"])
                )
    print_csv("table1_main (server accuracy per method × alpha)", rows)
    return rows


if __name__ == "__main__":
    main()

"""§Perf hillclimb driver (deliverable g): the three selected pairs, each
iterated hypothesis → change → measure on the dominant roofline term.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb [--pair NAME] [--list-pairs]

Every iteration re-lowers + recompiles the production program with one
lever changed and reports the three roofline terms; the narrative lives in
EXPERIMENTS.md §Perf. NOTE: must run in a fresh process (sets the 512-device
dry-run XLA flag).
"""
import os

os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
from typing import Callable, Optional

from repro.utils import get_logger

log = get_logger("hillclimb")


def dryrun_one(*args, **kwargs):
    """Deferred import: the dryrun stack needs a jax with sharding.AxisType;
    keeping it lazy lets the live-market pairs (epochdrv) run everywhere."""
    from repro.launch.dryrun import dryrun_one as _dryrun_one

    return _dryrun_one(*args, **kwargs)


def show(tag, rec):
    if rec["status"] != "ok":
        log.error("%s: %s %s", tag, rec["status"], rec.get("error", rec.get("reason")))
        return rec
    log.info(
        "%-38s c=%8.4fs m=%8.4fs k=%8.4fs dom=%-10s ratio=%5.3f hbm=%5.1fG fits=%s",
        tag,
        rec["compute_s"],
        rec["memory_s"],
        rec["collective_s"],
        rec["dominant"],
        rec.get("useful_flops_ratio", 0),
        rec["peak_bytes_per_device"] / 2**30,
        rec["fits_hbm"],
    )
    return rec


def pair_qwen3moe(out):
    """Worst roofline fraction: qwen3-moe-235b × train_4k.
    H1: the GShard dispatch/combine einsums (2·T·E·C·d each, ≈10³× the
        useful expert FLOPs at E=128, C=160) dominate compute → scatter
        dispatch removes them.
    H2: f32 momentum+grads are ~7.6 GB/dev of the HBM overrun → bf16 slots.
    H3: dispatch-einsum FLOPs scale with capacity C ∝ group size → smaller
        groups shrink the einsum even without the scatter rewrite."""
    a, s = "qwen3-moe-235b-a22b", "train_4k"
    out["qwen3moe:baseline(einsum,f32-slots)"] = show(
        "qwen3moe baseline einsum/f32", dryrun_one(a, s, verbose=False)
    )
    out["qwen3moe:it1(scatter)"] = show(
        "it1 moe_impl=scatter", dryrun_one(a, s, verbose=False, overrides={"moe_impl": "scatter"})
    )
    out["qwen3moe:it2(scatter+bf16-slots)"] = show(
        "it2 +bf16 momentum/grads",
        dryrun_one(
            a, s, verbose=False,
            overrides={"moe_impl": "scatter"},
            tc_overrides={"state_dtype": "bfloat16", "grad_dtype": "bfloat16"},
        ),
    )
    out["qwen3moe:it3(einsum,group512)"] = show(
        "it3 einsum group=512 (capacity lever)",
        dryrun_one(a, s, verbose=False, overrides={"moe_group_size": 512}),
    )
    out["qwen3moe:it4(group512+bf16+micro4)"] = show(
        "it4 group512 + bf16 slots + microbatch=4",
        dryrun_one(
            a, s, verbose=False, overrides={"moe_group_size": 512},
            tc_overrides={"state_dtype": "bfloat16", "grad_dtype": "bfloat16", "microbatches": 4},
        ),
    )
    out["qwen3moe:it5(group512+bf16+micro8)"] = show(
        "it5 group512 + bf16 slots + microbatch=8 (FITS)",
        dryrun_one(
            a, s, verbose=False, overrides={"moe_group_size": 512},
            tc_overrides={"state_dtype": "bfloat16", "grad_dtype": "bfloat16", "microbatches": 8},
        ),
    )


def pair_mixtral(out):
    """Most collective-bound: mixtral-8x7b × train_4k.
    H1: 8 experts cannot shard the 16-wide model axis → the rules fall back
        to tensor-parallel d_ff, paying an all-reduce per expert matmul; a
        (32, 8) mesh lets experts shard fully (expert parallelism).
    H2: the scatter dispatch removes the dispatch-einsum FLOPs/bytes on top."""
    a, s = "mixtral-8x7b", "train_4k"
    out["mixtral:baseline(16x16)"] = show(
        "mixtral baseline 16x16", dryrun_one(a, s, verbose=False)
    )
    out["mixtral:it1(mesh32x8)"] = show(
        "it1 mesh=32x8 (expert parallel)", dryrun_one(a, s, verbose=False, mesh_shape="32x8")
    )
    out["mixtral:it2(mesh32x8+scatter)"] = show(
        "it2 +scatter dispatch",
        dryrun_one(a, s, verbose=False, mesh_shape="32x8", overrides={"moe_impl": "scatter"}),
    )
    out["mixtral:it3(mesh32x8+scatter+bf16)"] = show(
        "it3 +bf16 slots",
        dryrun_one(
            a, s, verbose=False, mesh_shape="32x8",
            overrides={"moe_impl": "scatter"},
            tc_overrides={"state_dtype": "bfloat16", "grad_dtype": "bfloat16"},
        ),
    )
    out["mixtral:it4(mesh32x8+group512)"] = show(
        "it4 mesh32x8 einsum group=512 (E·C 8× smaller)",
        dryrun_one(a, s, verbose=False, mesh_shape="32x8", overrides={"moe_group_size": 512}),
    )
    out["mixtral:it6(mesh32x8+group512+micro8)"] = show(
        "it6 +microbatch=8",
        dryrun_one(
            a, s, verbose=False, mesh_shape="32x8",
            overrides={"moe_group_size": 512},
            tc_overrides={"microbatches": 8},
        ),
    )
    out["mixtral:it8(mesh32x8+group512+micro16)"] = show(
        "it8 +microbatch=16 (FITS)",
        dryrun_one(
            a, s, verbose=False, mesh_shape="32x8",
            overrides={"moe_group_size": 512},
            tc_overrides={"microbatches": 16},
        ),
    )


def pair_coboost(out):
    """Most paper-representative: the K=4-client Co-Boosting distillation
    step on granite-3-2b × train_4k.
    H1: accumulating the teacher ensemble as full (B,S,V) f32 logits is the
        memory hot spot (≈0.8 GB/dev × several live copies at 49k vocab) →
        chunking the KL over the sequence (heads factored out of the
        forwards) bounds live vocab tensors to (B, chunk, V).
    H2: bf16 optimizer slots shave the server-side state."""
    a, s = "granite-3-2b", "train_4k"
    out["coboost:baseline(K4)"] = show(
        "coboost baseline K=4", dryrun_one(a, s, verbose=False, coboost_clients=4)
    )
    out["coboost:it1(kl_chunk512)"] = show(
        "it1 kl_chunk=512", dryrun_one(a, s, verbose=False, coboost_clients=4, kl_chunk=512)
    )
    out["coboost:it2(kl_chunk512+bf16)"] = show(
        "it2 +bf16 slots",
        dryrun_one(
            a, s, verbose=False, coboost_clients=4, kl_chunk=512,
            tc_overrides={"state_dtype": "bfloat16", "grad_dtype": "bfloat16"},
        ),
    )


def _coboost_ab(arms, cfg, classes, shape, short, long, archs=None, grouped_market=False):
    """Shared live-market Co-Boosting A/B harness: each arm is
    ``(name, cfg_overrides, run_kwargs)``, timed as the difference of a long
    and a short run so compile + market setup cancel. Returns the epochs/sec
    record plus each arm's final server params (for parity checks).
    ``archs`` (one per client, default all-mlp) makes the market
    heterogeneous; ``grouped_market=True`` trains the clients through the
    vmapped build_market_grouped path (one program per arch group — the only
    sane way to stand up a K=64 market on CPU)."""
    from functools import partial

    import jax

    from repro.core import default_image_setup, run_coboosting
    from repro.data import make_synth_images
    from repro.fed import build_market, build_market_grouped
    from repro.models.cnn import cnn_apply, init_cnn

    x, y = make_synth_images(0, classes, 40, shape)
    archs = list(archs) if archs else ["mlp"] * cfg.num_clients
    if grouped_market:
        bank, bank_params, _, _ = build_market_grouped(0, x, y, cfg, classes, archs=archs)
        params = bank.unstack_params(bank_params)
        applies = [bank.client_apply(k) for k in range(bank.num_clients)]
    else:
        applies, params, _, _ = build_market(0, x, y, cfg, classes, archs=archs)
    server_apply = partial(cnn_apply, "mlp")

    def run(cfg_overrides, run_kwargs, epochs):
        c = dataclasses.replace(cfg, epochs=epochs, **cfg_overrides)
        sp = init_cnn(jax.random.key(99), "mlp", classes, shape)
        gen_apply, gp = default_image_setup(jax.random.key(5), c, classes, shape)
        t0 = time.time()
        st = run_coboosting(
            applies, params, server_apply, sp, gen_apply, gp, c, classes,
            jax.random.key(0), **run_kwargs,
        )
        jax.block_until_ready(st.server_params)
        return time.time() - t0, st

    rec, finals = {"status": "ok", "epochs": long - short}, {}
    for name, cfg_overrides, run_kwargs in arms:
        dt_long, st = run(cfg_overrides, run_kwargs, long)
        dt_short, _ = run(cfg_overrides, run_kwargs, short)
        finals[name] = st.server_params
        rec[f"{name}_epochs_per_sec"] = round((long - short) / max(dt_long - dt_short, 1e-9), 3)
    return rec, finals


def pair_epochdrv(out):
    """Epoch-driver hillclimb (the device-resident buffer PR's headline
    number): Co-Boosting epochs/sec, fused single-dispatch scan engine vs
    the legacy per-batch dispatch loop, on a miniature live market."""
    from repro.config.train import OFLConfig

    cfg = OFLConfig(
        num_clients=3, local_epochs=2, local_batch_size=16,
        gen_iters=4, batch_size=16, latent_dim=8, buffer_batches=6,
    )
    rec, _ = _coboost_ab(
        [("legacy", {}, {"driver": "legacy"}), ("fused", {}, {"driver": "fused"})],
        cfg, classes=4, shape=(8, 8, 3), short=4, long=16,
    )
    rec["buffer_batches"] = cfg.buffer_batches
    rec["speedup"] = round(rec["fused_epochs_per_sec"] / rec["legacy_epochs_per_sec"], 3)
    log.info(
        "epochdrv: fused=%.2f ep/s legacy=%.2f ep/s speedup=%.2fx (buffer=%d)",
        rec["fused_epochs_per_sec"], rec["legacy_epochs_per_sec"], rec["speedup"],
        cfg.buffer_batches,
    )
    out["epochdrv:fused_vs_legacy"] = rec


def pair_kernelpath(out):
    """Kernel-vs-ref loss path A/B under the fused epoch engine: Co-Boosting
    with the Eq. 4/Eq. 6 losses routed through the differentiable Pallas
    kernels (compiled on TPU, interpreter elsewhere) vs the pure-jnp ref
    composition, same PRNG stream. Reports epochs/sec for both arms, a
    loss-op microbench with a forward-only arm AND a full train-step
    (forward+backward+update) arm — the passes the fused Pallas VJPs now
    own — plus the final-server-params and one-step grad parity gaps.
    Off-TPU the interpreter arm is expected
    to be much slower — the number that matters there is the parity gap; the
    speed story is the TPU run."""
    import jax
    import jax.numpy as jnp

    from repro.config.train import OFLConfig
    from repro.kernels import kernel_arm

    arm = kernel_arm()
    cfg = OFLConfig(
        num_clients=3, local_epochs=2, local_batch_size=16,
        gen_iters=3, batch_size=16, latent_dim=8, buffer_batches=4,
    )
    rec, finals = _coboost_ab(
        [("ref", {"kernel_backend": "ref"}, {}), ("kernel", {"kernel_backend": arm}, {})],
        cfg, classes=4, shape=(8, 8, 3), short=2, long=6,
    )
    rec["kernel_arm"] = arm
    rec["jax_backend"] = jax.default_backend()
    rec["kernel_vs_ref_speedup"] = round(
        rec["kernel_epochs_per_sec"] / rec["ref_epochs_per_sec"], 3
    )
    rec["server_params_max_diff"] = float(
        max(
            jnp.max(jnp.abs(u.astype(jnp.float32) - v.astype(jnp.float32)))
            for u, v in zip(
                jax.tree_util.tree_leaves(finals["ref"]),
                jax.tree_util.tree_leaves(finals["kernel"]),
            )
        )
    )
    log.info(
        "kernelpath: kernel(%s)=%.2f ep/s ref=%.2f ep/s speedup=%.2fx parity=%.2e",
        arm, rec["kernel_epochs_per_sec"], rec["ref_epochs_per_sec"],
        rec["kernel_vs_ref_speedup"], rec["server_params_max_diff"],
    )

    # --- loss-op microbench: forward-only vs full train step (fwd+bwd) ---
    # Now that the Pallas backwards are fused kernels behind the same
    # custom_vjp, the A/B must separate the two passes: the forward-only arm
    # times just the dispatched loss eval, the train-step arm times a whole
    # value_and_grad + SGD update through BOTH losses (the distillation hot
    # path the fused VJPs serve). Same long-minus-short timing so dispatch
    # and compile cancel.
    from functools import partial

    from repro.kernels import ensemble_kl, ghm_ce

    K, B, V, D = 3, 32, 256, 64
    ks = jax.random.split(jax.random.key(7), 5)
    cl = jax.random.normal(ks[0], (K, B, V)) * 2.0
    x = jax.random.normal(ks[1], (B, D))
    w = jax.nn.softmax(jax.random.normal(ks[2], (K,)))
    labels = jax.random.randint(ks[3], (B,), 0, V)
    head = {
        "w": jax.random.normal(ks[4], (D, V)) / jnp.sqrt(D),
        "b": jnp.zeros((V,)),
    }

    def loss(params, backend):
        st = x @ params["w"] + params["b"]
        return jnp.mean(ensemble_kl(cl, st, w, temperature=4.0, backend=backend)) + jnp.mean(
            ghm_ce(cl, labels, w, backend=backend)
        )

    def train_step(params, backend):
        val, g = jax.value_and_grad(partial(loss, backend=backend))(params)
        return jax.tree_util.tree_map(lambda p, d: p - 0.1 * d, params, g), val

    def steps_per_sec(fn, short=3, long=13):
        def run(n):
            t0 = time.time()
            for _ in range(n):
                r = fn()
            jax.block_until_ready(r)
            return time.time() - t0

        run(1)  # compile
        dt_long, dt_short = run(long), run(short)
        return (long - short) / max(dt_long - dt_short, 1e-9)

    for mode, fn in (
        ("fwd", lambda backend: jax.jit(partial(loss, backend=backend))),
        ("train_step", lambda backend: jax.jit(partial(train_step, backend=backend))),
    ):
        for name, backend in (("ref", "ref"), ("kernel", arm)):
            f = fn(backend)
            thunk = (lambda f=f: f(head)) if mode == "fwd" else (lambda f=f: f(head)[0])
            rec[f"{mode}_{name}_steps_per_sec"] = round(steps_per_sec(thunk), 2)
        rec[f"{mode}_kernel_vs_ref_speedup"] = round(
            rec[f"{mode}_kernel_steps_per_sec"] / max(rec[f"{mode}_ref_steps_per_sec"], 1e-9), 3
        )
    # one-step grad parity on the exact microbench program
    g_ref = jax.grad(partial(loss, backend="ref"))(head)
    g_ker = jax.grad(partial(loss, backend=arm))(head)
    rec["train_step_grads_max_diff"] = float(
        max(
            jnp.max(jnp.abs(u - v))
            for u, v in zip(
                jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_ker)
            )
        )
    )
    rec["microbench_kbvd"] = [K, B, V, D]
    log.info(
        "kernelpath microbench (K=%d B=%d V=%d): fwd kernel=%.1f ref=%.1f it/s "
        "(%.2fx) | train-step kernel=%.1f ref=%.1f it/s (%.2fx) grad-parity=%.2e",
        K, B, V,
        rec["fwd_kernel_steps_per_sec"], rec["fwd_ref_steps_per_sec"],
        rec["fwd_kernel_vs_ref_speedup"],
        rec["train_step_kernel_steps_per_sec"], rec["train_step_ref_steps_per_sec"],
        rec["train_step_kernel_vs_ref_speedup"], rec["train_step_grads_max_diff"],
    )
    out["kernelpath:kernel_vs_ref"] = rec


def pair_servepath(out):
    """Serving-path A/B (the continuous-batching PR's headline number):
    R staggered requests with RAGGED generation budgets against the reduced
    smollm-135m server model, continuous slot engine vs the fused
    static-batch baseline. Static pays twice: each batch dispatches only
    once its last member has arrived, and the whole batch decodes to its
    LONGEST member's budget (the tail bubble — short requests ride along as
    dead slots). The engine admits each prompt on arrival and refills a slot
    the moment its sequence drains — that is the tok/s and latency gap, and
    it is budget-raggedness-shaped, not hardware-speed-shaped."""
    import jax
    import numpy as np

    from repro.config import get_arch, reduced_variant
    from repro.models import init_lm
    from repro.serve import (
        ContinuousScheduler, EngineConfig, ServeEngine, ragged_stream,
        static_generate, with_arrivals,
    )
    from repro.serve.metrics import percentile as pct

    # serve-scale quick variant: deep/wide enough that a decode step costs
    # ~5ms — the regime the engine exists for. At the 2-layer smoke scale
    # a decode step is ~1ms and BOTH arms are pure dispatch overhead, which
    # measures the host, not the batching policy.
    cfg = reduced_variant(get_arch("smollm-135m")).replace(
        dtype="float32", param_dtype="float32", num_layers=4, d_model=256,
    )
    params = init_lm(cfg, jax.random.key(0))
    R, PROMPT, MAX_GEN, BATCH, REPEATS = 16, 32, 48, 4, 5
    prompts, budgets = ragged_stream(cfg.vocab_size, R, PROMPT, MAX_GEN, seed=0)

    engine = ServeEngine(
        cfg, params,
        EngineConfig(max_slots=BATCH, max_seq=PROMPT + MAX_GEN, max_new=MAX_GEN, decode_chunk=8),
    )
    sched = ContinuousScheduler(engine)

    def mk_requests(dt):
        return with_arrivals(prompts, budgets, dt)

    def run_static(dt):
        """Batches of BATCH in arrival order; each batch dispatches once its
        last member has arrived and decodes to its longest budget (every
        request's tokens land when the single fused dispatch returns).
        Useful tok/s counts only each request's own budget."""
        lat, t0, useful = [], time.time(), 0
        for b0 in range(0, R, BATCH):
            ridx = list(range(b0, min(b0 + BATCH, R)))
            gate = max(i * dt for i in ridx)
            wait = t0 + gate - time.time()
            if wait > 0:
                time.sleep(wait)
            toks = np.stack([prompts[i] for i in ridx])
            gen = max(budgets[i] for i in ridx)
            jax.block_until_ready(
                static_generate(params, cfg, {"tokens": jax.numpy.asarray(toks)}, gen)
            )
            t_done = time.time() - t0
            useful += sum(budgets[i] for i in ridx)
            lat += [t_done - i * dt for i in ridx]
        return useful / max(time.time() - t0, 1e-9), lat

    def run_continuous(dt):
        t0 = time.time()
        comps = sched.run(mk_requests(dt))
        wall = time.time() - t0
        return sum(len(c.tokens) for c in comps) / max(wall, 1e-9), [c.latency for c in comps]

    # warm both compile caches, then calibrate the arrival gap to the
    # hardware: all R requests arrive within ~half the static arm's total
    # service time. Staggered enough that admission interleaves with decode,
    # loaded enough that freed slots always have queued work to grab — the
    # regime continuous batching exists for (light load degenerates to both
    # engines idling at the arrival rate; heavy load is pure batch service).
    run_static(0.0)
    t0 = time.time()
    run_static(0.0)
    dt = max((time.time() - t0) / (2 * R), 1e-3)
    engine.warmup(prompts[0])  # every pow2 admit size + the chunk program
    run_continuous(0.0)

    # median of interleaved repeats: the per-run service time is small at
    # quick scale, so a single OS hiccup would otherwise decide the A/B
    st_runs, ct_runs = [], []
    for _ in range(REPEATS):
        st_runs.append(run_static(dt))
        ct_runs.append(run_continuous(dt))
    st_tps, st_lat = sorted(st_runs, key=lambda r: r[0])[REPEATS // 2]
    ct_tps, ct_lat = sorted(ct_runs, key=lambda r: r[0])[REPEATS // 2]
    rec = {
        "status": "ok",
        "requests": R, "prompt_len": PROMPT,
        "budgets": budgets, "batch_and_slots": BATCH, "arrival_dt_s": round(dt, 4),
        "static_tok_per_s": round(st_tps, 2),
        "continuous_tok_per_s": round(ct_tps, 2),
        "speedup": round(ct_tps / max(st_tps, 1e-9), 3),
        "static_p50_s": round(pct(st_lat, 50), 4),
        "static_p95_s": round(pct(st_lat, 95), 4),
        "continuous_p50_s": round(pct(ct_lat, 50), 4),
        "continuous_p95_s": round(pct(ct_lat, 95), 4),
        "decode_chunks": engine.stats["decode_chunks"],
        "host_syncs": engine.stats["host_syncs"],
        "jax_backend": jax.default_backend(),
    }
    log.info(
        "servepath: continuous=%.1f tok/s static=%.1f tok/s speedup=%.2fx "
        "p95 %.3fs vs %.3fs (dt=%.3fs)",
        ct_tps, st_tps, rec["speedup"], rec["continuous_p95_s"], rec["static_p95_s"], dt,
    )
    out["servepath:continuous_vs_static"] = rec


def pair_decodepath(out):
    """Decode-path A/B (the paged-KV PR's headline number): the SAME
    continuous engine + scheduler on both arms, R staggered requests with
    RAGGED budgets — only the KV layout differs. ``paged`` runs the KVPool +
    flash-decode path (``decode_backend="auto"``: the compiled Pallas kernel
    on TPU, its blocked-jnp ref twin elsewhere — auto never interprets, so
    the CPU number is an honest layout comparison); ``dense`` is the
    per-slot-rectangle + small-SDPA baseline. Median of interleaved repeats,
    staggered arrivals calibrated exactly like servepath."""
    import jax

    from repro.config import get_arch, reduced_variant
    from repro.kernels.dispatch import resolve_backend
    from repro.models import init_lm
    from repro.serve import (
        ContinuousScheduler, EngineConfig, ServeEngine, ragged_stream, with_arrivals,
    )
    from repro.serve.metrics import percentile as pct

    cfg = reduced_variant(get_arch("smollm-135m")).replace(
        dtype="float32", param_dtype="float32", num_layers=4, d_model=256,
    )
    params = init_lm(cfg, jax.random.key(0))
    R, PROMPT, MAX_GEN, SLOTS, REPEATS = 16, 32, 48, 4, 5
    PAGE = 16
    prompts, budgets = ragged_stream(cfg.vocab_size, R, PROMPT, MAX_GEN, seed=0)

    def mk_engine(layout):
        return ServeEngine(
            cfg, params,
            EngineConfig(
                max_slots=SLOTS, max_seq=PROMPT + MAX_GEN, max_new=MAX_GEN,
                decode_chunk=8, kv_layout=layout, page_size=PAGE,
            ),
        )

    engines = {"dense": mk_engine("dense"), "paged": mk_engine("paged")}
    scheds = {k: ContinuousScheduler(e) for k, e in engines.items()}

    def run_arm(name, dt):
        t0 = time.time()
        comps = scheds[name].run(with_arrivals(prompts, budgets, dt))
        wall = time.time() - t0
        return sum(len(c.tokens) for c in comps) / max(wall, 1e-9), [c.latency for c in comps]

    # warm both compile caches, calibrate arrivals to the dense arm's service
    # time (both arms then see the identical arrival schedule)
    for name, eng in engines.items():
        eng.warmup(prompts[0])
        run_arm(name, 0.0)
    t0 = time.time()
    run_arm("dense", 0.0)
    dt = max((time.time() - t0) / (2 * R), 1e-3)

    runs = {"dense": [], "paged": []}
    for _ in range(REPEATS):
        for name in ("dense", "paged"):
            runs[name].append(run_arm(name, dt))
    med = {k: sorted(v, key=lambda r: r[0])[REPEATS // 2] for k, v in runs.items()}
    pool = engines["paged"].pool
    rec = {
        "status": "ok",
        "requests": R, "prompt_len": PROMPT, "budgets": budgets,
        "slots": SLOTS, "page_size": PAGE, "pool_pages": pool.n_pages,
        "arrival_dt_s": round(dt, 4),
        "decode_backend": resolve_backend("auto"),
        "dense_tok_per_s": round(med["dense"][0], 2),
        "paged_tok_per_s": round(med["paged"][0], 2),
        "speedup": round(med["paged"][0] / max(med["dense"][0], 1e-9), 3),
        "dense_p50_s": round(pct(med["dense"][1], 50), 4),
        "dense_p95_s": round(pct(med["dense"][1], 95), 4),
        "paged_p50_s": round(pct(med["paged"][1], 50), 4),
        "paged_p95_s": round(pct(med["paged"][1], 95), 4),
        "page_appends": engines["paged"].stats["page_appends"],
        "jax_backend": jax.default_backend(),
    }
    log.info(
        "decodepath: paged=%.1f tok/s dense=%.1f tok/s speedup=%.2fx "
        "p95 %.3fs vs %.3fs (backend=%s, %d pages x %d)",
        rec["paged_tok_per_s"], rec["dense_tok_per_s"], rec["speedup"],
        rec["paged_p95_s"], rec["dense_p95_s"], rec["decode_backend"],
        rec["pool_pages"], PAGE,
    )
    out["decodepath:paged_vs_dense"] = rec


def pair_fleetpath(out):
    """Fleet-path A/B (the serving-fleet PR's headline number): the SAME
    staggered ragged request stream against (A) one monolithic colocated
    ServeEngine with 2N slots and (B) a FleetRouter over two N-slot
    replicas — equal total slot/pool capacity — with replica 0 running as
    an explicitly disaggregated prefill/decode worker pair (the handoff
    path in the timed loop). Both arms run meshless on this process's
    devices, so the CPU number isolates the ROUTING + handoff overhead
    (parity of tokens is pinned by tests/test_fleet.py); the fleet's win on
    real hardware is replicas on disjoint mesh slices. Reports tok/s and
    end-to-end p50/p95 like the other serve pairs PLUS the queue-wait
    percentiles (admitted - arrival) that the Completion split now makes
    visible — the router-attributable share of latency."""
    import jax

    from repro.config import get_arch, reduced_variant
    from repro.models import init_lm
    from repro.serve import (
        ContinuousScheduler, EngineConfig, FleetRouter, ServeEngine,
        ragged_stream, with_arrivals,
    )
    from repro.serve.metrics import percentile as pct

    cfg = reduced_variant(get_arch("smollm-135m")).replace(
        dtype="float32", param_dtype="float32", num_layers=4, d_model=256,
    )
    params = init_lm(cfg, jax.random.key(0))
    R, PROMPT, MAX_GEN, SLOTS, REPEATS = 16, 32, 48, 4, 5
    prompts, budgets = ragged_stream(cfg.vocab_size, R, PROMPT, MAX_GEN, seed=0)

    def mk_ecfg(slots, disagg=False):
        return EngineConfig(
            max_slots=slots, max_seq=PROMPT + MAX_GEN, max_new=MAX_GEN,
            decode_chunk=8, disagg=disagg,
        )

    mono = ServeEngine(cfg, params, mk_ecfg(SLOTS))
    replicas = [
        ServeEngine(cfg, params, mk_ecfg(SLOTS // 2, disagg=True)),
        ServeEngine(cfg, params, mk_ecfg(SLOTS // 2)),
    ]
    arms = {
        "mono": ContinuousScheduler(mono),
        "fleet": FleetRouter(replicas),
    }

    def run_arm(name, dt):
        t0 = time.time()
        comps = arms[name].run(with_arrivals(prompts, budgets, dt))
        wall = time.time() - t0
        return (
            sum(len(c.tokens) for c in comps) / max(wall, 1e-9),
            [c.latency for c in comps],
            [c.queue_wait for c in comps],
        )

    # warm every compile cache (both replicas + the monolith), calibrate the
    # arrival gap to the monolith's service time exactly like servepath
    for eng in [mono] + replicas:
        eng.warmup(prompts[0])
    run_arm("mono", 0.0)
    run_arm("fleet", 0.0)
    t0 = time.time()
    run_arm("mono", 0.0)
    dt = max((time.time() - t0) / (2 * R), 1e-3)

    runs = {"mono": [], "fleet": []}
    for _ in range(REPEATS):
        for name in ("mono", "fleet"):
            runs[name].append(run_arm(name, dt))
    med = {k: sorted(v, key=lambda r: r[0])[REPEATS // 2] for k, v in runs.items()}
    rec = {
        "status": "ok",
        "requests": R, "prompt_len": PROMPT, "budgets": budgets,
        "mono_slots": SLOTS, "fleet_replicas": len(replicas),
        "fleet_slots_per_replica": SLOTS // 2, "disagg_replicas": 1,
        "arrival_dt_s": round(dt, 4),
        "mono_tok_per_s": round(med["mono"][0], 2),
        "fleet_tok_per_s": round(med["fleet"][0], 2),
        "speedup": round(med["fleet"][0] / max(med["mono"][0], 1e-9), 3),
        "mono_p50_s": round(pct(med["mono"][1], 50), 4),
        "mono_p95_s": round(pct(med["mono"][1], 95), 4),
        "fleet_p50_s": round(pct(med["fleet"][1], 50), 4),
        "fleet_p95_s": round(pct(med["fleet"][1], 95), 4),
        "mono_queue_wait_p50_s": round(pct(med["mono"][2], 50), 4),
        "mono_queue_wait_p95_s": round(pct(med["mono"][2], 95), 4),
        "fleet_queue_wait_p50_s": round(pct(med["fleet"][2], 50), 4),
        "fleet_queue_wait_p95_s": round(pct(med["fleet"][2], 95), 4),
        "handoffs": sum(e.stats["handoffs"] for e in replicas),
        "requeued": arms["fleet"].stats["requeued"],
        "jax_backend": jax.default_backend(),
    }
    # telemetry-on guard arm: the SAME fleet stream with the process-global
    # span tracer + registry enabled (what --trace-out/--metrics-out switch
    # on). Spans bracket once-per-dispatch host actions only, so the enabled
    # path must stay within run-to-run noise of the plain fleet arm —
    # telemetry_overhead drifting above ~1.05 flags a hot-path regression.
    from repro import obs

    obs.configure(metrics=True, trace=True)
    try:
        tel = [run_arm("fleet", dt) for _ in range(REPEATS)]
    finally:
        trace_events = len(obs.tracer())
        obs.configure(metrics=False, trace=False)
    tel_tok = sorted(t[0] for t in tel)[REPEATS // 2]
    rec["fleet_telemetry_tok_per_s"] = round(tel_tok, 2)
    rec["telemetry_overhead"] = round(med["fleet"][0] / max(tel_tok, 1e-9), 3)
    rec["telemetry_trace_events"] = trace_events
    log.info(
        "fleetpath: fleet=%.1f tok/s mono=%.1f tok/s speedup=%.2fx "
        "p95 %.3fs vs %.3fs queue-wait p95 %.3fs vs %.3fs (%d handoffs) "
        "telemetry-on=%.1f tok/s (overhead %.2fx, %d spans)",
        rec["fleet_tok_per_s"], rec["mono_tok_per_s"], rec["speedup"],
        rec["fleet_p95_s"], rec["mono_p95_s"],
        rec["fleet_queue_wait_p95_s"], rec["mono_queue_wait_p95_s"],
        rec["handoffs"], rec["fleet_telemetry_tok_per_s"],
        rec["telemetry_overhead"], rec["telemetry_trace_events"],
    )
    out["fleetpath:router_disagg_vs_mono"] = rec


def pair_specpath(out):
    """Shared-prefix + speculative-decoding A/B (the prefix-cache PR's
    headline number): the SAME hot-prefix request stream — >=50% of prompts
    open with a common 24-token head — against (A) the plain paged engine
    and (B) the same engine with the radix prefix cache and the
    ensemble-drafter speculative decoder enabled. The headline is PREFILL
    WORK: hot admissions splice the shared head's pages out of the cache
    and prefill only the uncovered tail, so pages_allocated and
    prefill_tokens drop roughly with the shared fraction while greedy
    tokens stay bitwise identical (pinned by tests/test_serve.py).

    The drafter is the target itself (same config + params): a random-init
    repro has no trained drafter/target pair, so the pair exercises the
    MATCHED-drafter limit — acceptance ~1.0, every verify certifying k+1
    tokens — which checks the full draft/verify/emit path at its ceiling;
    any registry drafter plugs into the same (dcfg, dparams) slot. tok/s,
    p50/p95, prefix hit rate and draft acceptance rate are all recorded."""
    import jax

    from repro.config import get_arch, reduced_variant
    from repro.models import init_lm
    from repro.serve import (
        ContinuousScheduler, EngineConfig, ServeEngine, hot_prefix_stream,
        with_arrivals,
    )
    from repro.serve.metrics import percentile as pct

    cfg = reduced_variant(get_arch("smollm-135m")).replace(
        dtype="float32", param_dtype="float32", num_layers=4, d_model=256,
    )
    params = init_lm(cfg, jax.random.key(0))
    R, PROMPT, MAX_GEN, SLOTS, REPEATS = 16, 32, 48, 4, 5
    PAGE, SHARED, HEAD, SPEC_K = 8, 0.6, 24, 4
    prompts, budgets = hot_prefix_stream(
        cfg.vocab_size, R, PROMPT, MAX_GEN, seed=0,
        shared_fraction=SHARED, prefix_len=HEAD,
    )

    def mk_ecfg(**kw):
        # prefill_bucket == page size: a spliced admission's uncovered tail
        # bills its true length instead of padding back up to the default
        # 32-token bucket (plain prompts are exactly 32 tokens either way).
        return EngineConfig(
            max_slots=SLOTS, max_seq=PROMPT + MAX_GEN, max_new=MAX_GEN,
            decode_chunk=8, kv_layout="paged", page_size=PAGE,
            prefill_bucket=PAGE, **kw,
        )

    engines = {
        "plain": ServeEngine(cfg, params, mk_ecfg()),
        "boosted": ServeEngine(
            cfg, params, mk_ecfg(prefix_cache=True, spec_k=SPEC_K),
            drafter=(cfg, params),
        ),
    }
    scheds = {k: ContinuousScheduler(e) for k, e in engines.items()}

    def run_arm(name, dt):
        t0 = time.time()
        comps = scheds[name].run(with_arrivals(prompts, budgets, dt))
        wall = time.time() - t0
        return sum(len(c.tokens) for c in comps) / max(wall, 1e-9), [c.latency for c in comps]

    # warm both compile caches (the boosted warmup also traces the splice
    # and spec programs), calibrate arrivals to the plain arm's service time
    for name, eng in engines.items():
        eng.warmup(prompts[0])
        run_arm(name, 0.0)
    t0 = time.time()
    run_arm("plain", 0.0)
    dt = max((time.time() - t0) / (2 * R), 1e-3)

    runs = {"plain": [], "boosted": []}
    for _ in range(REPEATS):
        for name in ("plain", "boosted"):
            runs[name].append(run_arm(name, dt))
    med = {k: sorted(v, key=lambda r: r[0])[REPEATS // 2] for k, v in runs.items()}
    # schedulers reset the engine (and its stats) at the top of every run,
    # so each stats dict now holds exactly the LAST timed pass of the stream
    ps, bs = engines["plain"].stats, engines["boosted"].stats
    admitted = max(bs["admitted"], 1)
    proposed = max(bs["draft_proposed"], 1)
    rec = {
        "status": "ok",
        "requests": R, "prompt_len": PROMPT, "budgets": budgets,
        "slots": SLOTS, "page_size": PAGE, "spec_k": SPEC_K,
        "shared_fraction": SHARED, "prefix_len": HEAD,
        "arrival_dt_s": round(dt, 4),
        "plain_tok_per_s": round(med["plain"][0], 2),
        "boosted_tok_per_s": round(med["boosted"][0], 2),
        "speedup": round(med["boosted"][0] / max(med["plain"][0], 1e-9), 3),
        "plain_p50_s": round(pct(med["plain"][1], 50), 4),
        "plain_p95_s": round(pct(med["plain"][1], 95), 4),
        "boosted_p50_s": round(pct(med["boosted"][1], 50), 4),
        "boosted_p95_s": round(pct(med["boosted"][1], 95), 4),
        # the headline: prefill work per pass of the identical stream
        "plain_prefill_tokens": ps["prefill_tokens"],
        "boosted_prefill_tokens": bs["prefill_tokens"],
        "plain_pages_allocated": ps["pages_allocated"],
        "boosted_pages_allocated": bs["pages_allocated"],
        "plain_prefill_dispatches": ps["prefill_dispatches"],
        "boosted_prefill_dispatches": bs["prefill_dispatches"],
        "prefix_hit_rate": round(bs["spliced_admissions"] / admitted, 3),
        "spliced_admissions": bs["spliced_admissions"],
        "spliced_pages": bs["spliced_pages"],
        "cow_copies": bs["cow_copies"],
        "draft_acceptance_rate": round(bs["draft_accepted"] / proposed, 3),
        "spec_steps": bs["spec_steps"],
        "jax_backend": jax.default_backend(),
    }
    log.info(
        "specpath: boosted=%.1f tok/s plain=%.1f tok/s speedup=%.2fx | "
        "prefill tokens %d->%d pages %d->%d dispatches %d->%d | "
        "hit rate %.0f%% (%d spliced pages, %d CoW) acceptance %.0f%%",
        rec["boosted_tok_per_s"], rec["plain_tok_per_s"], rec["speedup"],
        rec["plain_prefill_tokens"], rec["boosted_prefill_tokens"],
        rec["plain_pages_allocated"], rec["boosted_pages_allocated"],
        rec["plain_prefill_dispatches"], rec["boosted_prefill_dispatches"],
        100 * rec["prefix_hit_rate"], rec["spliced_pages"], rec["cow_copies"],
        100 * rec["draft_acceptance_rate"],
    )
    out["specpath:prefix_spec_vs_plain"] = rec


def _ensemblepath_setup(args):
    """Parse --ks into the K sweep (setup hook)."""
    spec = getattr(args, "ks", "") or "8,32"
    return {"ks": [int(k) for k in spec.split(",")]}


def pair_ensemblepath(out, args=None, ctx=None):
    """Grouped-ensemble A/B (the ClientBank PR's headline number): the SAME
    fused Co-Boosting epoch program on a MIXED-ARCH live market, client
    forwards routed through the grouped ClientBank (one vmap per arch group,
    O(#groups) trace) vs the K-way python-unrolled loop (O(K) trace). Same
    PRNG stream, so the final server params double as the parity check.

    The headline is END-TO-END epochs/sec for a quick-scale run, compile
    included: the bank's O(#groups) trace collapses the unrolled program's
    trace+compile cost, which at K=32 dwarfs the steady-state epochs of a
    short run (and grows with K, while the bank's stays flat). Steady-state
    s/epoch and trace+compile seconds are reported separately so the two
    effects stay distinguishable. Sweeps K via --ks (default 8,32; the full
    story adds 64)."""
    import dataclasses as _dc
    import time as _time
    from functools import partial

    import jax
    import jax.numpy as jnp

    from repro.config.train import OFLConfig
    from repro.core import default_image_setup, run_coboosting
    from repro.data import make_synth_images
    from repro.fed import build_market_grouped
    from repro.models.cnn import cnn_apply, init_cnn

    classes, shape = 4, (8, 8, 3)
    SHORT, LONG = 2, 10
    x, y = make_synth_images(0, classes, 40, shape)
    for K in (ctx or _ensemblepath_setup(args))["ks"]:
        cfg = OFLConfig(
            num_clients=K, local_epochs=1, local_batch_size=16,
            gen_iters=3, batch_size=16, latent_dim=8, buffer_batches=4,
        )
        archs = [("mlp", "cnn2")[k % 2] for k in range(K)]  # 2 arch groups
        bank, bank_params, _, _ = build_market_grouped(0, x, y, cfg, classes, archs=archs)
        params = bank.unstack_params(bank_params)
        applies = [bank.client_apply(k) for k in range(K)]
        server_apply = partial(cnn_apply, "mlp")

        def run(impl, epochs):
            # each call builds fresh jitted programs, so one wall-clock run
            # is exactly trace+compile + epochs * steady
            c = _dc.replace(cfg, epochs=epochs, ensemble_impl=impl)
            sp = init_cnn(jax.random.key(99), "mlp", classes, shape)
            gen_apply, gp = default_image_setup(jax.random.key(5), c, classes, shape)
            t0 = _time.time()
            st = run_coboosting(
                applies, params, server_apply, sp, gen_apply, gp, c, classes,
                jax.random.key(0),
            )
            jax.block_until_ready(st.server_params)
            return _time.time() - t0, st

        rec = {"status": "ok", "epochs": LONG, "num_clients": K,
               "num_groups": bank.num_groups, "jax_backend": jax.default_backend()}
        finals = {}
        for impl in ("looped", "grouped"):
            t_long, st = run(impl, LONG)
            t_short, _ = run(impl, SHORT)
            finals[impl] = st.server_params
            steady = max(t_long - t_short, 1e-9) / (LONG - SHORT)
            rec[f"{impl}_epochs_per_sec"] = round(LONG / t_long, 3)
            rec[f"{impl}_steady_s_per_epoch"] = round(steady, 3)
            rec[f"{impl}_compile_s"] = round(max(t_long - LONG * steady, 0.0), 3)
        rec["speedup"] = round(
            rec["grouped_epochs_per_sec"] / rec["looped_epochs_per_sec"], 3
        )
        rec["compile_speedup"] = round(
            rec["looped_compile_s"] / max(rec["grouped_compile_s"], 1e-9), 3
        )
        rec["server_params_max_diff"] = float(
            max(
                jnp.max(jnp.abs(u.astype(jnp.float32) - v.astype(jnp.float32)))
                for u, v in zip(
                    jax.tree_util.tree_leaves(finals["looped"]),
                    jax.tree_util.tree_leaves(finals["grouped"]),
                )
            )
        )
        log.info(
            "ensemblepath K=%d: grouped=%.2f ep/s looped=%.2f ep/s speedup=%.2fx "
            "(compile %.1fs vs %.1fs, steady %.2f vs %.2f s/ep) parity=%.2e (%d groups)",
            K, rec["grouped_epochs_per_sec"], rec["looped_epochs_per_sec"],
            rec["speedup"], rec["grouped_compile_s"], rec["looped_compile_s"],
            rec["grouped_steady_s_per_epoch"], rec["looped_steady_s_per_epoch"],
            rec["server_params_max_diff"], rec["num_groups"],
        )
        out[f"ensemblepath:K{K}"] = rec


def _ensemblepath_report(out):
    """Report hook: one summary line over the K sweep."""
    recs = {k: v for k, v in out.items() if k.startswith("ensemblepath:")}
    if recs:
        log.info(
            "ensemblepath summary: %s",
            {k.split(":")[1]: f'{v["speedup"]}x' for k, v in recs.items()},
        )


@dataclasses.dataclass(frozen=True)
class PairSpec:
    """One registry entry: ``setup(args) -> ctx`` builds shared context,
    ``run(out, args, ctx)`` fills ``out`` with records, ``report(out)``
    prints a cross-record summary. Legacy single-argument pair functions are
    adapted via :func:`_nullary`."""

    help: str
    run: Callable
    setup: Optional[Callable] = None
    report: Optional[Callable] = None

    def execute(self, out, args):
        ctx = self.setup(args) if self.setup else None
        self.run(out, args, ctx)
        if self.report:
            self.report(out)


def _nullary(fn):
    """Adapt a classic ``fn(out)`` pair function to the hook signature."""
    return lambda out, args, ctx: fn(out)


PAIRS = {
    "qwen3moe": PairSpec(
        help="MoE dryrun hillclimb: qwen3-moe-235b x train_4k (worst roofline)",
        run=_nullary(pair_qwen3moe),
    ),
    "mixtral": PairSpec(
        help="MoE dryrun hillclimb: mixtral-8x7b x train_4k (most collective-bound)",
        run=_nullary(pair_mixtral),
    ),
    "coboost": PairSpec(
        help="LM-scale Co-Boosting distillation dryrun: granite-3-2b x train_4k",
        run=_nullary(pair_coboost),
    ),
    "epochdrv": PairSpec(
        help="fused single-dispatch epoch engine vs legacy per-batch loop (live market)",
        run=_nullary(pair_epochdrv),
    ),
    "kernelpath": PairSpec(
        help="Pallas fused-loss kernels vs pure-jnp ref under the fused epoch engine",
        run=_nullary(pair_kernelpath),
    ),
    "servepath": PairSpec(
        help="continuous-batching engine vs fused static-batch serving",
        run=_nullary(pair_servepath),
    ),
    "decodepath": PairSpec(
        help="paged KVPool + flash-decode vs dense per-slot KV + SDPA",
        run=_nullary(pair_decodepath),
    ),
    "fleetpath": PairSpec(
        help="routed fleet (2 replicas, one disaggregated pair) vs monolithic engine",
        run=_nullary(pair_fleetpath),
    ),
    "specpath": PairSpec(
        help="radix prefix cache + speculative decoding vs plain paged engine "
             "on hot-prefix traffic",
        run=_nullary(pair_specpath),
    ),
    "ensemblepath": PairSpec(
        help="grouped ClientBank ensemble vs K-way looped client forwards (mixed archs)",
        run=pair_ensemblepath,
        setup=_ensemblepath_setup,
        report=_ensemblepath_report,
    ),
}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--pair", default="all", choices=list(PAIRS) + ["all"])
    p.add_argument("--list-pairs", action="store_true", help="print the registry and exit")
    p.add_argument("--ks", default="", help="ensemblepath client-count sweep, e.g. 8,32,64")
    p.add_argument("--out", default="results/perf_hillclimb.json")
    args = p.parse_args()
    if args.list_pairs:
        for name, spec in PAIRS.items():
            print(f"{name:14s} {spec.help}")
        return
    out = {}
    for name, spec in PAIRS.items():
        if args.pair in (name, "all"):
            spec.execute(out, args)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1, default=str)


if __name__ == "__main__":
    main()

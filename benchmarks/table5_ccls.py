"""Table 5/10 — C_cls partition (each client holds only C classes).
Expected: Co-Boosting > DENSE at every C; gap largest at small C."""
from __future__ import annotations

from benchmarks.common import SCALE, bench_setting, get_scale, print_csv


def main(cs=None) -> list:
    sc = get_scale()
    cs = cs or ((2, 3, 4, 5) if SCALE == "full" else (2,))
    # fedavg included even at quick scale: disjoint class shards are where
    # parameter averaging collapses while logit distillation survives
    methods = ("fedavg", "dense", "coboosting") if SCALE == "full" else ("fedavg", "coboosting")
    rows = []
    for c in cs:
        for seed in sc.seeds:
            res = bench_setting(methods, sc, seed=seed, partition="c_cls", c_cls=c)
            for m, r in res.items():
                rows.append(dict(c_cls=c, seed=seed, method=m,
                                 server_acc=round(r["server_acc"], 4),
                                 ensemble_acc=round(r["ensemble_acc"], 4)))
    print_csv("table5_ccls (C-classes-per-client partition)", rows)
    return rows


if __name__ == "__main__":
    main()

"""Tables 18/19 (App. B.7) — hyperparameter sensitivity: DHS perturbation
strength ε and EE step size µ. Not in the default `benchmarks.run` set
(adds ~20 min); run directly:

    PYTHONPATH=src python -m benchmarks.table19_sensitivity
"""
from __future__ import annotations

from benchmarks.common import SCALE, bench_setting, get_scale, print_csv


def main(eps_values=None, mu_values=None) -> list:
    sc = get_scale()
    eps_values = eps_values or ((1 / 255, 4 / 255, 8 / 255, 16 / 255) if SCALE == "full" else (2 / 255, 8 / 255, 32 / 255))
    mu_values = mu_values or ((0.005, 0.05, 0.1) if SCALE == "full" else (0.01, 0.1))
    rows = []
    for eps in eps_values:
        res = bench_setting(("coboosting",), sc, seed=0, epsilon=eps)
        r = res["coboosting"]
        rows.append(dict(param="epsilon", value=round(eps, 5),
                         server_acc=round(r["server_acc"], 4),
                         ensemble_acc=round(r["ensemble_acc"], 4)))
    for mu in mu_values:
        res = bench_setting(("coboosting",), sc, seed=0, mu=mu)
        r = res["coboosting"]
        rows.append(dict(param="mu", value=mu,
                         server_acc=round(r["server_acc"], 4),
                         ensemble_acc=round(r["ensemble_acc"], 4)))
    print_csv("table19_sensitivity (DHS epsilon / EE mu sweeps)", rows)
    return rows


if __name__ == "__main__":
    main()

"""Benchmark entry point: one module per paper table + kernel micro-bench.

    PYTHONPATH=src python -m benchmarks.run [--tables t1,t7,kernels|all]

Default (quick scale) runs every table at reduced size; set
``REPRO_BENCH_SCALE=full`` for paper-scale sweeps (hours).
Output: CSV blocks per table (what EXPERIMENTS.md §Paper-validation cites).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    kernels_bench,
    table1_main,
    table2_ensemble,
    table3_hetero,
    table4_unbalanced,
    table5_ccls,
    table6_clients,
    table7_ablation,
)
from benchmarks.common import SCALE

TABLES = {
    "kernels": ("kernels", kernels_bench.main),
    "t1": ("table1_main", table1_main.main),
    "t2": ("table2_ensemble", table2_ensemble.main),
    "t3": ("table3_hetero", table3_hetero.main),
    "t4": ("table4_unbalanced", table4_unbalanced.main),
    "t5": ("table5_ccls", table5_ccls.main),
    "t6": ("table6_clients", table6_clients.main),
    "t7": ("table7_ablation", table7_ablation.main),
}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument(
        "--tables",
        default="kernels,t1,t2,t5,t7",
        help=f"comma list from {list(TABLES)} or 'all'",
    )
    args = p.parse_args()
    names = list(TABLES) if args.tables == "all" else args.tables.split(",")
    print(f"# benchmark scale: {SCALE}; tables: {names}", flush=True)
    t0 = time.time()
    for n in names:
        label, fn = TABLES[n]
        print(f"## running {label} ...", file=sys.stderr, flush=True)
        t1 = time.time()
        fn()
        print(f"## {label} done in {time.time()-t1:.0f}s", file=sys.stderr, flush=True)
    print(f"# all benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()

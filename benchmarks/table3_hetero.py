"""Table 3/15 — heterogeneous client architectures (the model-market
setting the paper targets): each client a different CNN family; FedAvg is
inapplicable. Expected: Co-Boosting > DENSE/F-* under heterogeneity."""
from __future__ import annotations

from benchmarks.common import SCALE, bench_setting, get_scale, print_csv

HETERO_ARCHS = ("cnn5", "cnn2", "miniresnet", "mlp", "lenet5")


def main() -> list:
    sc = get_scale()
    rows = []
    methods = ("feddf", "f_dafl", "dense", "coboosting") if SCALE == "full" else ("dense", "coboosting")
    n = sc.clients
    archs = [HETERO_ARCHS[i % len(HETERO_ARCHS)] for i in range(n)]
    for seed in sc.seeds:
        res = bench_setting(methods, sc, seed=seed, alpha=0.1, archs=archs, server_arch="miniresnet")
        for m, r in res.items():
            rows.append(dict(seed=seed, method=m, archs="|".join(archs),
                             server_acc=round(r["server_acc"], 4),
                             ensemble_acc=round(r["ensemble_acc"], 4)))
    print_csv("table3_hetero (heterogeneous client archs, ResNet-family server)", rows)
    return rows


if __name__ == "__main__":
    main()

"""Shared harness for the paper-table benchmarks.

The paper's tables are CIFAR-scale; this container is a 2-core CPU, so each
table runs a *scaled* instance on SynthDigits (DESIGN.md §6): fewer clients,
smaller images, shorter schedules. The validation target is the paper's
QUALITATIVE orderings (Co-Boosting > DENSE/F-* > FedAvg; Co-Boosting
ensemble > FedENS; every ablation component helps), not CIFAR point
accuracies. Scale presets:

  quick — the default for ``python -m benchmarks.run`` (minutes);
  full  — closer to the paper's sizes (hours; opt-in via REPRO_BENCH_SCALE).
"""
from __future__ import annotations

import dataclasses
import os
import time
from functools import lru_cache, partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.config.train import OFLConfig
from repro.data import make_synth_images
from repro.fed import build_market
from repro.launch.ofl import run_method
from repro.utils import get_logger

log = get_logger("bench")

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")


@dataclasses.dataclass(frozen=True)
class BenchScale:
    classes: int = 6
    image: int = 16
    per_class: int = 120
    test_per_class: int = 40
    clients: int = 3
    local_epochs: int = 10
    epochs: int = 10
    gen_iters: int = 8
    batch: int = 32
    buffer_batches: int = 3
    client_arch: str = "cnn2"
    server_arch: str = "cnn2"
    seeds: Tuple[int, ...] = (0,)


QUICK = BenchScale()
FULL = BenchScale(
    classes=10,
    image=32,
    per_class=400,
    test_per_class=100,
    clients=10,
    local_epochs=40,
    epochs=60,
    gen_iters=20,
    batch=64,
    buffer_batches=6,
    client_arch="cnn5",
    server_arch="cnn5",
    seeds=(0, 1, 2),
)


def get_scale() -> BenchScale:
    return FULL if SCALE == "full" else QUICK


def make_cfg(sc: BenchScale, seed: int = 0, **overrides) -> OFLConfig:
    base = dict(
        num_clients=sc.clients,
        partition="dirichlet",
        alpha=0.1,
        local_epochs=sc.local_epochs,
        epochs=sc.epochs,
        gen_iters=sc.gen_iters,
        batch_size=sc.batch,
        latent_dim=32,
        buffer_batches=sc.buffer_batches,
        seed=seed,
    )
    base.update(overrides)
    return OFLConfig(**base)


@lru_cache(maxsize=4)
def _data(sc: BenchScale, seed: int):
    x, y = make_synth_images(seed, sc.classes, sc.per_class, (sc.image, sc.image, 3))
    tx, ty = make_synth_images(seed + 1, sc.classes, sc.test_per_class, (sc.image, sc.image, 3))
    return x, y, tx, ty


_MARKET_CACHE: Dict = {}


def get_market(sc: BenchScale, cfg: OFLConfig, seed: int, archs: Optional[Sequence[str]] = None):
    """Local training is method-independent; cache it per (partition, seed)."""
    key = (sc, cfg.partition, cfg.alpha, cfg.c_cls, cfg.lognormal_sigma, cfg.num_clients, seed, tuple(archs or ()))
    if key not in _MARKET_CACHE:
        x, y, tx, ty = _data(sc, seed)
        archs_list = list(archs) if archs else [sc.client_arch] * cfg.num_clients
        market = build_market(seed, x, y, cfg, sc.classes, archs_list)
        _MARKET_CACHE[key] = (market, (x, y, tx, ty))
    return _MARKET_CACHE[key]


def bench_setting(
    methods: Sequence[str],
    sc: BenchScale,
    seed: int = 0,
    archs: Optional[Sequence[str]] = None,
    server_arch: Optional[str] = None,
    **cfg_overrides,
) -> Dict[str, Dict[str, float]]:
    """Run a list of methods on one partition setting; returns
    {method: {server_acc, ensemble_acc, seconds}}."""
    cfg = make_cfg(sc, seed, **cfg_overrides)
    (applies, params, sizes, _), (x, y, tx, ty) = get_market(sc, cfg, seed, archs)
    out: Dict[str, Dict[str, float]] = {}
    for m in methods:
        t0 = time.time()
        res = run_method(
            m, cfg, sc.classes, (sc.image, sc.image, 3), applies, params, sizes,
            x, tx, ty, server_arch or sc.server_arch, seed, eval_every=max(cfg.epochs, 1),
        )
        res = {k: float(v) for k, v in res.items() if isinstance(v, (int, float))}
        res["seconds"] = round(time.time() - t0, 1)
        out[m] = res
        log.info("  %-12s server=%.3f ensemble=%.3f (%.0fs)", m, res.get("server_acc", -1), res.get("ensemble_acc", -1), res["seconds"])
    return out


def print_csv(table: str, rows: List[Dict]) -> None:
    if not rows:
        return
    keys = list(rows[0].keys())
    print(f"# {table}")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    print()

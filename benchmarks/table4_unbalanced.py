"""Table 4 / Fig. 2 — unbalanced client data amounts (lognormal σ).
Expected: Co-Boosting ensemble > DW-FedENS > FedENS, growing with σ."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import SCALE, bench_setting, get_market, get_scale, make_cfg, print_csv
from repro.core import data_amount_weights, make_logits_all, uniform_weights
from repro.fed import market_eval_fn
from repro.models.cnn import cnn_apply, init_cnn
from functools import partial


def main(sigmas=None) -> list:
    sc = get_scale()
    sigmas = sigmas or ((0.4, 0.8, 1.2) if SCALE == "full" else (0.8,))
    rows = []
    for sigma in sigmas:
        for seed in sc.seeds:
            cfg = make_cfg(sc, seed, lognormal_sigma=sigma)
            (applies, params, sizes, _), (x, y, tx, ty) = get_market(sc, cfg, seed)
            server_apply = partial(cnn_apply, sc.server_arch)
            dummy = init_cnn(jax.random.key(1), sc.server_arch, sc.classes, (sc.image, sc.image, 3))
            eval_fn = market_eval_fn(applies, params, server_apply, tx, ty)
            fedens = eval_fn(dummy, uniform_weights(len(params)))["ensemble_acc"]
            dw = eval_fn(dummy, data_amount_weights(sizes))["ensemble_acc"]
            res = bench_setting(("coboosting",), sc, seed=seed, lognormal_sigma=sigma)
            rows.append(
                dict(sigma=sigma, seed=seed,
                     fedens=round(fedens, 4), dw_fedens=round(dw, 4),
                     coboosting_ens=round(res["coboosting"]["ensemble_acc"], 4),
                     coboosting_server=round(res["coboosting"]["server_acc"], 4))
            )
    print_csv("table4_unbalanced (lognormal data amounts: ensemble quality)", rows)
    return rows


if __name__ == "__main__":
    main()

"""Table 2/9 — ensemble accuracy: FedENS (uniform weights) vs the
Co-Boosting learned-weight ensemble, per heterogeneity level."""
from __future__ import annotations

from benchmarks.common import SCALE, bench_setting, get_scale, print_csv


def main(alphas=None) -> list:
    sc = get_scale()
    alphas = alphas or ((0.05, 0.1, 0.3) if SCALE == "full" else (0.1, 0.3))
    rows = []
    for alpha in alphas:
        for seed in sc.seeds:
            res = bench_setting(("fedens", "coboosting"), sc, seed=seed, alpha=alpha)
            rows.append(
                dict(alpha=alpha, seed=seed,
                     fedens_ensemble=round(res["fedens"]["ensemble_acc"], 4),
                     coboosting_ensemble=round(res["coboosting"]["ensemble_acc"], 4))
            )
    print_csv("table2_ensemble (FedENS vs Co-Boosting ensemble accuracy)", rows)
    return rows


if __name__ == "__main__":
    main()

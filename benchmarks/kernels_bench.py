"""Kernel micro-benchmarks: the Pallas kernel arm (compiled on TPU,
interpreter on CPU — see ``kernel_arm``) vs the pure-jnp reference. Wall
times on CPU measure the *reference* path meaningfully and the interpreter
only at correctness scale; the TPU story lives in the roofline analysis.
Also reports allclose deltas. The ``us_kernel`` column is whichever arm
the header names."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_csv
from repro.kernels import (
    ensemble_kl,
    ensemble_kl_ref,
    flash_attention,
    flash_attention_ref,
    ghm_ce,
    ghm_ce_ref,
    kernel_arm,
)

KER = kernel_arm()


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def main() -> list:
    rows = []
    key = jax.random.key(0)

    # ensemble_kl
    k, b, v = 8, 64, 2048
    cl = jax.random.normal(key, (k, b, v))
    st = jax.random.normal(jax.random.key(1), (b, v))
    w = jax.nn.softmax(jax.random.normal(jax.random.key(2), (k,)))
    got = ensemble_kl(cl, st, w, temperature=4.0, backend=KER)
    want = ensemble_kl_ref(cl, st, w, 4.0)
    err = float(jnp.max(jnp.abs(got - want)))
    us_ref = _time(jax.jit(lambda a, b2, c: ensemble_kl_ref(a, b2, c, 4.0)), cl, st, w)
    us_ker = _time(lambda a, b2, c: ensemble_kl(a, b2, c, temperature=4.0, backend=KER), cl, st, w)
    rows.append(dict(kernel="ensemble_kl", shape=f"K{k}xB{b}xV{v}", max_err=f"{err:.2e}",
                     us_ref=round(us_ref), us_kernel=round(us_ker)))

    # ghm_ce
    lbl = jax.random.randint(jax.random.key(3), (b,), 0, v)
    got = ghm_ce(cl, lbl, w, backend=KER)
    want = ghm_ce_ref(cl, lbl, w)
    err = float(jnp.max(jnp.abs(got - want)))
    us_ref = _time(jax.jit(lambda a, l, c: ghm_ce_ref(a, l, c)), cl, lbl, w)
    us_ker = _time(lambda a, l, c: ghm_ce(a, l, c, backend=KER), cl, lbl, w)
    rows.append(dict(kernel="ghm_ce", shape=f"K{k}xB{b}xV{v}", max_err=f"{err:.2e}",
                     us_ref=round(us_ref), us_kernel=round(us_ker)))

    # flash attention
    bq, s, h, kh, hd = 2, 256, 4, 2, 64
    q = jax.random.normal(key, (bq, s, h, hd))
    kk = jax.random.normal(jax.random.key(4), (bq, s, kh, hd))
    vv = jax.random.normal(jax.random.key(5), (bq, s, kh, hd))
    got = flash_attention(q, kk, vv, causal=True, backend=KER, block_q=64, block_kv=64)
    want = flash_attention_ref(q, kk, vv, causal=True)
    err = float(jnp.max(jnp.abs(got - want)))
    us_ref = _time(jax.jit(lambda a, b2, c: flash_attention_ref(a, b2, c, causal=True)), q, kk, vv)
    us_ker = _time(lambda a, b2, c: flash_attention(a, b2, c, causal=True, backend=KER, block_q=64, block_kv=64), q, kk, vv)
    rows.append(dict(kernel="flash_attention", shape=f"B{bq}xS{s}xH{h}/{kh}xD{hd}", max_err=f"{err:.2e}",
                     us_ref=round(us_ref), us_kernel=round(us_ker)))

    print_csv(f"kernels (arm={KER}: correctness + timing)", rows)
    return rows


if __name__ == "__main__":
    main()
